//! Execution tracing: per-matrix activation digests + cross-backend diff.
//!
//! A recorded [`ExecTrace`] is an ordered list of (step, layer, op, lane,
//! digest) events, one per GQMV output produced by `forward_batch`.  The
//! digest is a cheap order-sensitive 64-bit FNV-1a hash over the raw f32 bit
//! patterns of the output tensor, so two traces match iff every hashed
//! activation is bit-identical — the same contract the bit-exactness tests
//! assert, but localizable: [`diff`] reports the *first* divergent op with
//! exact (step, layer, matrix, lane) coordinates instead of a bare `assert`
//! failure on final logits.
//!
//! Traces serialize to a line-oriented text format (see [`ExecTrace::to_text`])
//! so `llamaf trace-diff` can compare recordings made by different backends
//! (host vs device runtime, resident vs streamed, layer vs matrix granularity)
//! or even different builds.

use std::fmt;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::LlamaConfig;

/// Hard cap on recorded events per trace: a runaway generation degrades to a
/// truncated trace instead of unbounded memory growth (~24 MiB at the cap).
pub const MAX_EVENTS: usize = 1 << 20;

/// Order-sensitive 64-bit FNV-1a over the little-endian bit patterns of each
/// `f32`.  Distinguishes `0.0` from `-0.0` and any NaN payload difference —
/// exactly as strict as the repo's bit-exactness contract.
pub fn digest64(vals: &[f32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for v in vals {
        for b in v.to_bits().to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

/// Which GQMV output a [`TraceEvent`] digests (mirrors `MatKind`, minus the
/// shapes: trace coordinates name the op, geometry lives in the header).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceOp {
    /// Fused Wq‖Wk‖Wv output (pre-RoPE).
    Qkv,
    /// Attention output projection (pre-residual).
    Wo,
    /// Fused W1‖W3 output (pre-SwiGLU).
    W13,
    /// FFN down-projection (pre-residual).
    W2,
    /// Classifier logits (recorded with `layer == n_layers`).
    Cls,
}

impl TraceOp {
    /// Stable wire/CLI label for this op.
    pub fn label(self) -> &'static str {
        match self {
            TraceOp::Qkv => "qkv",
            TraceOp::Wo => "wo",
            TraceOp::W13 => "w13",
            TraceOp::W2 => "w2",
            TraceOp::Cls => "cls",
        }
    }

    /// Inverse of [`TraceOp::label`].
    pub fn parse(s: &str) -> Option<TraceOp> {
        Some(match s {
            "qkv" => TraceOp::Qkv,
            "wo" => TraceOp::Wo,
            "w13" => TraceOp::W13,
            "w2" => TraceOp::W2,
            "cls" => TraceOp::Cls,
            _ => return None,
        })
    }
}

impl fmt::Display for TraceOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Consumer of per-op digest events emitted by the forward pass.
///
/// [`ExecTrace`] is the canonical sink (it stores the events); the batch
/// scheduler's lane router implements it too, forwarding each lane's
/// events into that lane's *per-request* trace with the lane renumbered
/// to 0 — which is what lets a request decoded inside an arbitrary batch
/// be diffed against a batch-1 recording of the same prompt.
pub trait TraceSink {
    /// Open a new forward step; subsequent [`TraceSink::record`] calls
    /// belong to it.
    fn begin_step(&mut self);
    /// Digest `vals` produced at (`layer`, `op`, `lane`) in the current
    /// step.
    fn record(&mut self, layer: usize, op: TraceOp, lane: usize, vals: &[f32]);
}

impl TraceSink for ExecTrace {
    fn begin_step(&mut self) {
        ExecTrace::begin_step(self);
    }

    fn record(&mut self, layer: usize, op: TraceOp, lane: usize, vals: &[f32]) {
        ExecTrace::record(self, layer, op, lane, vals);
    }
}

/// One digested GQMV output: where it happened and what it hashed to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// Forward step index within the trace (0-based; one per `forward_batch`).
    pub step: u32,
    /// Transformer layer index; `n_layers` for the classifier.
    pub layer: u32,
    /// Which matrix output was digested.
    pub op: TraceOp,
    /// Batch lane index within the step (always 0 for batch-1 engines).
    pub lane: u32,
    /// [`digest64`] of the op's output tensor for that lane.
    pub digest: u64,
}

/// A recorded execution trace: model geometry, a backend label, and the
/// ordered digest events captured during `forward_batch`.
#[derive(Clone, Debug)]
pub struct ExecTrace {
    cfg: LlamaConfig,
    label: String,
    events: Vec<TraceEvent>,
    steps: u32,
    truncated: bool,
}

impl ExecTrace {
    /// Start an empty trace for a model with the given geometry.  `label`
    /// names the producing backend (e.g. `Engine::name()`); it is carried in
    /// the file but never compared by [`diff`].
    pub fn new(cfg: &LlamaConfig, label: &str) -> Self {
        ExecTrace {
            cfg: *cfg,
            label: label.to_string(),
            events: Vec::new(),
            steps: 0,
            truncated: false,
        }
    }

    /// Open a new forward step; subsequent [`ExecTrace::record`] calls are
    /// stamped with its index.
    pub fn begin_step(&mut self) {
        self.steps += 1;
    }

    /// Digest `vals` and append an event at (current step, `layer`, `op`,
    /// `lane`).  Silently stops recording (and marks the trace truncated)
    /// once [`MAX_EVENTS`] is reached.
    pub fn record(&mut self, layer: usize, op: TraceOp, lane: usize, vals: &[f32]) {
        debug_assert!(self.steps > 0, "record() before begin_step()");
        if self.events.len() >= MAX_EVENTS {
            self.truncated = true;
            return;
        }
        self.events.push(TraceEvent {
            step: self.steps.saturating_sub(1),
            layer: layer as u32,
            op,
            lane: lane as u32,
            digest: digest64(vals),
        });
    }

    /// Discard the current (most recent) step: pop its events and step
    /// the counter back.  Used by the batch scheduler's fault path — a
    /// forward step that errors mid-flight is retried, and the retry
    /// must not leave the aborted attempt's partial events in the
    /// trace (they would diff as a schedule mismatch against a clean
    /// run).  No-op on an empty trace.
    pub fn rollback_step(&mut self) {
        if self.steps == 0 {
            return;
        }
        let cur = self.steps - 1;
        while self.events.last().map(|e| e.step == cur).unwrap_or(false) {
            self.events.pop();
        }
        self.steps -= 1;
    }

    /// Model geometry the trace was recorded against.
    pub fn cfg(&self) -> &LlamaConfig {
        &self.cfg
    }

    /// Backend label supplied at [`ExecTrace::new`] time.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Recorded events, in execution order.
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when no events were recorded.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of forward steps begun.
    pub fn steps(&self) -> u32 {
        self.steps
    }

    /// True if recording hit [`MAX_EVENTS`] and dropped the tail.
    pub fn truncated(&self) -> bool {
        self.truncated
    }

    /// Serialize to the `llamaf-trace v1` text format:
    ///
    /// ```text
    /// llamaf-trace v1
    /// label cpu-resident/scalar
    /// geom dim=64 hidden=128 layers=2 heads=2 kv_heads=1 vocab=512 seq=64 gs=32
    /// e <step> <layer> <op> <lane> <digest:016x>
    /// ...
    /// end <count>
    /// ```
    pub fn to_text(&self) -> String {
        let c = &self.cfg;
        let mut out = String::with_capacity(64 + self.events.len() * 24);
        out.push_str("llamaf-trace v1\n");
        out.push_str(&format!("label {}\n", self.label));
        out.push_str(&format!(
            "geom dim={} hidden={} layers={} heads={} kv_heads={} vocab={} seq={} gs={}\n",
            c.dim, c.hidden_dim, c.n_layers, c.n_heads, c.n_kv_heads, c.vocab_size, c.seq_len, c.gs
        ));
        for e in &self.events {
            out.push_str(&format!(
                "e {} {} {} {} {:016x}\n",
                e.step,
                e.layer,
                e.op.label(),
                e.lane,
                e.digest
            ));
        }
        let tail = if self.truncated { " truncated" } else { "" };
        out.push_str(&format!("end {}{}\n", self.events.len(), tail));
        out
    }

    /// Parse the text format produced by [`ExecTrace::to_text`].
    pub fn parse(text: &str) -> Result<ExecTrace> {
        let mut lines = text.lines();
        let header = lines.next().context("empty trace file")?;
        if header.trim() != "llamaf-trace v1" {
            bail!("not a llamaf trace (bad header: '{header}')");
        }
        let label_line = lines.next().context("missing label line")?;
        let label =
            label_line.strip_prefix("label ").context("second line must be 'label <text>'")?;
        let geom_line = lines.next().context("missing geom line")?;
        let geom = geom_line.strip_prefix("geom ").context("third line must be 'geom ...'")?;
        let mut g = std::collections::HashMap::new();
        for kv in geom.split_whitespace() {
            let (k, v) = kv.split_once('=').with_context(|| format!("bad geom field '{kv}'"))?;
            g.insert(k, v.parse::<usize>().with_context(|| format!("geom {k}='{v}'"))?);
        }
        let get = |k: &str| g.get(k).copied().with_context(|| format!("geom missing '{k}'"));
        let cfg = LlamaConfig {
            dim: get("dim")?,
            hidden_dim: get("hidden")?,
            n_layers: get("layers")?,
            n_heads: get("heads")?,
            n_kv_heads: get("kv_heads")?,
            vocab_size: get("vocab")?,
            seq_len: get("seq")?,
            gs: get("gs")?,
        };
        let mut events = Vec::new();
        let mut footer: Option<(usize, bool)> = None;
        for line in lines {
            let line = line.trim_end();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("end ") {
                let mut it = rest.split_whitespace();
                let n: usize = it.next().context("end line missing count")?.parse()?;
                let truncated = it.next() == Some("truncated");
                footer = Some((n, truncated));
                break;
            }
            let rest = line.strip_prefix("e ").with_context(|| format!("bad line '{line}'"))?;
            let mut it = rest.split_whitespace();
            let mut next = || it.next().with_context(|| format!("short event line '{line}'"));
            let step: u32 = next()?.parse()?;
            let layer: u32 = next()?.parse()?;
            let op_s = next()?;
            let op = TraceOp::parse(op_s).with_context(|| format!("unknown op '{op_s}'"))?;
            let lane: u32 = next()?.parse()?;
            let digest = u64::from_str_radix(next()?, 16)
                .with_context(|| format!("bad digest in '{line}'"))?;
            events.push(TraceEvent { step, layer, op, lane, digest });
        }
        let (count, truncated) = footer.context("trace missing 'end <count>' footer")?;
        if count != events.len() {
            bail!("trace footer says {count} events, found {}", events.len());
        }
        let steps = events.last().map(|e| e.step + 1).unwrap_or(0);
        Ok(ExecTrace { cfg, label: label.to_string(), events, steps, truncated })
    }

    /// Write the trace to `path` in the text format.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing trace {}", path.display()))
    }

    /// Load a trace previously written with [`ExecTrace::save`].
    pub fn load(path: &Path) -> Result<ExecTrace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        ExecTrace::parse(&text).with_context(|| format!("parsing trace {}", path.display()))
    }
}

/// The first event where two traces disagree on the digest while agreeing on
/// the coordinates — the earliest point the backends computed different bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Divergence {
    /// Event index into both traces.
    pub index: usize,
    /// Forward step of the divergent op.
    pub step: u32,
    /// Transformer layer (`n_layers` ⇒ classifier).
    pub layer: u32,
    /// Which matrix output diverged.
    pub op: TraceOp,
    /// Batch lane within the step.
    pub lane: u32,
    /// Digest recorded by trace `a`.
    pub a: u64,
    /// Digest recorded by trace `b`.
    pub b: u64,
}

/// Outcome of comparing two traces with [`diff`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DiffOutcome {
    /// Same geometry, same schedule, every digest equal.
    Identical,
    /// The traces were recorded against different model geometries; digests
    /// are not comparable.  Carries the two `geom` header strings.
    GeometryMismatch {
        /// Geometry of trace `a`.
        a: String,
        /// Geometry of trace `b`.
        b: String,
    },
    /// The traces executed different op sequences (coordinates disagree
    /// before any digest does) — e.g. different prompts or batch shapes.
    ScheduleMismatch {
        /// Index of the first coordinate disagreement.
        index: usize,
        /// `step/layer/op/lane` of trace `a` at that index.
        a: String,
        /// `step/layer/op/lane` of trace `b` at that index.
        b: String,
    },
    /// Coordinates agree but at least one digest differs.
    Diverged {
        /// First divergent event.
        first: Divergence,
        /// Total number of divergent events over the compared prefix.
        total: usize,
    },
    /// All compared events match but one trace is longer.
    LengthMismatch {
        /// Event count of trace `a`.
        a: usize,
        /// Event count of trace `b`.
        b: usize,
    },
}

/// Result of [`diff`]: how many events were compared and what was found.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DiffReport {
    /// Number of event pairs compared (min of the two lengths).
    pub compared: usize,
    /// What the comparison found.
    pub outcome: DiffOutcome,
}

impl DiffReport {
    /// True iff the traces are bit-identical over their full length.
    pub fn identical(&self) -> bool {
        self.outcome == DiffOutcome::Identical
    }

    /// One-line human summary of the outcome (what `trace-diff` prints).
    pub fn summary(&self) -> String {
        match &self.outcome {
            DiffOutcome::Identical => {
                format!("identical: {} events compared, 0 divergences", self.compared)
            }
            DiffOutcome::GeometryMismatch { a, b } => {
                format!("geometry mismatch:\n  a: {a}\n  b: {b}")
            }
            DiffOutcome::ScheduleMismatch { index, a, b } => format!(
                "schedule mismatch at event {index}: a ran {a}, b ran {b} \
                 (different prompts or batch shapes?)"
            ),
            DiffOutcome::Diverged { first, total } => format!(
                "first divergence at event {}: step {} layer {} op {} lane {}: \
                 a={:016x} b={:016x} ({} divergent of {} compared)",
                first.index,
                first.step,
                first.layer,
                first.op,
                first.lane,
                first.a,
                first.b,
                total,
                self.compared
            ),
            DiffOutcome::LengthMismatch { a, b } => format!(
                "prefix identical ({} events) but lengths differ: a={a} b={b}",
                self.compared
            ),
        }
    }
}

/// Compare two traces event-by-event.  Geometry must match; then the op
/// schedules must match; then the first digest disagreement (if any) is
/// reported with its coordinates.
pub fn diff(a: &ExecTrace, b: &ExecTrace) -> DiffReport {
    if a.cfg != b.cfg {
        let geom = |t: &ExecTrace| {
            let c = t.cfg();
            format!(
                "dim={} hidden={} layers={} heads={} kv_heads={} vocab={} seq={} gs={}",
                c.dim,
                c.hidden_dim,
                c.n_layers,
                c.n_heads,
                c.n_kv_heads,
                c.vocab_size,
                c.seq_len,
                c.gs
            )
        };
        return DiffReport {
            compared: 0,
            outcome: DiffOutcome::GeometryMismatch { a: geom(a), b: geom(b) },
        };
    }
    let n = a.events.len().min(b.events.len());
    let coords = |e: &TraceEvent| format!("{}/{}/{}/{}", e.step, e.layer, e.op, e.lane);
    let mut first: Option<Divergence> = None;
    let mut total = 0usize;
    for i in 0..n {
        let (ea, eb) = (&a.events[i], &b.events[i]);
        if (ea.step, ea.layer, ea.op, ea.lane) != (eb.step, eb.layer, eb.op, eb.lane) {
            return DiffReport {
                compared: i,
                outcome: DiffOutcome::ScheduleMismatch { index: i, a: coords(ea), b: coords(eb) },
            };
        }
        if ea.digest != eb.digest {
            total += 1;
            if first.is_none() {
                first = Some(Divergence {
                    index: i,
                    step: ea.step,
                    layer: ea.layer,
                    op: ea.op,
                    lane: ea.lane,
                    a: ea.digest,
                    b: eb.digest,
                });
            }
        }
    }
    if let Some(first) = first {
        return DiffReport { compared: n, outcome: DiffOutcome::Diverged { first, total } };
    }
    if a.events.len() != b.events.len() {
        return DiffReport {
            compared: n,
            outcome: DiffOutcome::LengthMismatch { a: a.events.len(), b: b.events.len() },
        };
    }
    DiffReport { compared: n, outcome: DiffOutcome::Identical }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 512,
            seq_len: 64,
            gs: 32,
        }
    }

    // Goldens pin the exact FNV-1a-over-LE-f32-bits definition: a silent
    // change to the hash breaks cross-build trace comparison.
    #[test]
    fn digest_goldens() {
        assert_eq!(digest64(&[]), 0xcbf2_9ce4_8422_2325); // FNV offset basis
        assert_eq!(digest64(&[0.0]), 0x4d25_767f_9dce_13f5);
        assert_eq!(digest64(&[1.0]), 0x4b72_477f_9c5c_2f98);
        assert_eq!(digest64(&[1.0, 2.0]), 0x097a_69ee_2da3_01d8);
    }

    #[test]
    fn digest_is_order_and_sign_sensitive() {
        assert_ne!(digest64(&[1.0, 2.0]), digest64(&[2.0, 1.0]));
        assert_ne!(digest64(&[0.0]), digest64(&[-0.0]), "bit-exact: -0.0 != 0.0");
        assert_eq!(digest64(&[0.5, -3.25]), digest64(&[0.5, -3.25]));
    }

    fn sample_trace(label: &str) -> ExecTrace {
        let cfg = tiny_cfg();
        let mut t = ExecTrace::new(&cfg, label);
        for step in 0..3u32 {
            t.begin_step();
            for layer in 0..cfg.n_layers {
                for op in [TraceOp::Qkv, TraceOp::Wo, TraceOp::W13, TraceOp::W2] {
                    t.record(layer, op, 0, &[step as f32, layer as f32]);
                }
            }
            t.record(cfg.n_layers, TraceOp::Cls, 0, &[step as f32]);
        }
        t
    }

    #[test]
    fn text_round_trip_preserves_everything() {
        let t = sample_trace("cpu-resident/scalar");
        let back = ExecTrace::parse(&t.to_text()).unwrap();
        assert_eq!(back.cfg(), t.cfg());
        assert_eq!(back.label(), t.label());
        assert_eq!(back.events(), t.events());
        assert_eq!(back.steps(), t.steps());
        assert!(!back.truncated());
    }

    #[test]
    fn parse_rejects_corrupt_files() {
        assert!(ExecTrace::parse("").is_err());
        assert!(ExecTrace::parse("not a trace\n").is_err());
        let t = sample_trace("x");
        let text = t.to_text();
        // footer count mismatch
        let bad = text.replace(&format!("end {}", t.len()), "end 999");
        assert!(ExecTrace::parse(&bad).is_err());
        // missing footer
        let cut = text.rsplit_once("end").unwrap().0;
        assert!(ExecTrace::parse(cut).is_err());
    }

    #[test]
    fn rollback_erases_a_partial_step_exactly() {
        let clean = sample_trace("clean");
        // same schedule, but step 1 is attempted, aborted mid-flight,
        // rolled back, and re-run — the trace must come out identical
        let cfg = tiny_cfg();
        let mut t = ExecTrace::new(&cfg, "retried");
        let run_step = |t: &mut ExecTrace, step: u32| {
            t.begin_step();
            for layer in 0..cfg.n_layers {
                for op in [TraceOp::Qkv, TraceOp::Wo, TraceOp::W13, TraceOp::W2] {
                    t.record(layer, op, 0, &[step as f32, layer as f32]);
                }
            }
            t.record(cfg.n_layers, TraceOp::Cls, 0, &[step as f32]);
        };
        run_step(&mut t, 0);
        // aborted attempt: partial events, then rollback
        t.begin_step();
        t.record(0, TraceOp::Qkv, 0, &[99.0]);
        t.record(0, TraceOp::Wo, 0, &[98.0]);
        t.rollback_step();
        run_step(&mut t, 1);
        run_step(&mut t, 2);
        let r = diff(&clean, &t);
        assert!(r.identical(), "{}", r.summary());
        // rollback on empty is a no-op
        let mut e = ExecTrace::new(&cfg, "empty");
        e.rollback_step();
        assert_eq!(e.steps(), 0);
    }

    #[test]
    fn diff_identical_and_label_insensitive() {
        let a = sample_trace("host");
        let b = sample_trace("device");
        let r = diff(&a, &b);
        assert!(r.identical(), "{}", r.summary());
        assert_eq!(r.compared, a.len());
    }

    #[test]
    fn diff_reports_first_divergence_coordinates() {
        let a = sample_trace("a");
        let mut b = sample_trace("b");
        // perturb one known event: step 1, layer 1, op W13, lane 0
        let idx = b
            .events
            .iter()
            .position(|e| e.step == 1 && e.layer == 1 && e.op == TraceOp::W13)
            .unwrap();
        b.events[idx].digest ^= 1;
        let r = diff(&a, &b);
        match r.outcome {
            DiffOutcome::Diverged { first, total } => {
                assert_eq!(total, 1);
                assert_eq!(first.index, idx);
                assert_eq!(
                    (first.step, first.layer, first.op, first.lane),
                    (1, 1, TraceOp::W13, 0)
                );
                assert_eq!(first.a ^ first.b, 1);
            }
            other => panic!("expected Diverged, got {other:?}"),
        }
    }

    #[test]
    fn diff_distinguishes_schedule_geometry_and_length() {
        let a = sample_trace("a");
        // schedule: same length, different op at one slot
        let mut b = sample_trace("b");
        let i = 2;
        b.events[i].op = TraceOp::W2;
        match diff(&a, &b).outcome {
            DiffOutcome::ScheduleMismatch { index, .. } => assert_eq!(index, i),
            other => panic!("expected ScheduleMismatch, got {other:?}"),
        }
        // geometry
        let mut cfg2 = tiny_cfg();
        cfg2.dim = 128;
        let g = ExecTrace::new(&cfg2, "g");
        assert!(matches!(diff(&a, &g).outcome, DiffOutcome::GeometryMismatch { .. }));
        // length: identical prefix, one longer
        let mut c = sample_trace("c");
        c.events.pop();
        match diff(&a, &c).outcome {
            DiffOutcome::LengthMismatch { a: la, b: lb } => {
                assert_eq!((la, lb), (a.len(), a.len() - 1))
            }
            other => panic!("expected LengthMismatch, got {other:?}"),
        }
    }
}
