//! Replicated-serving gateway: one front process speaking the engine
//! server's line protocol to clients and multiplexing their sessions
//! across N engine-replica backends.
//!
//! Topology (`llamaf gateway --backends a,b,c`):
//!
//! ```text
//!   clients ──► accept loop ──► bounded conn queue ──► gateway workers
//!                                                        │ sticky pin
//!                                                        ▼
//!                                  router (least-loaded over Up > Degraded,
//!                                          bounded per-backend in-flight)
//!                                   │            │            │
//!                                   ▼            ▼            ▼
//!                               replica 0    replica 1    replica 2
//!                                   ▲            ▲            ▲
//!                                   └───── health prober ─────┘
//!                                          (HEALTH, per interval)
//! ```
//!
//! Robustness contract:
//!
//! * **Sticky sessions** — a client connection pins one replica
//!   connection for its lifetime, so the replica-side KV session (and
//!   `TRACE` state) stays on one engine.  The pin is chosen least-loaded
//!   at the first generation and re-chosen after a backend loss.
//! * **End-to-end backpressure** — the client connection queue and the
//!   per-backend in-flight bound (`--max-queue`) are both bounded;
//!   overflow is answered `ERR busy: ...` immediately, never queued
//!   unbounded, never silently dropped.
//! * **Retry-with-redirect** — a generation whose backend dies before
//!   *any* reply line reached the client is transparently re-routed to
//!   another live replica (greedy decoding is deterministic, so the
//!   redirected stream is the stream the dead replica would have sent).
//! * **Honest shedding** — a stream that dies after output started is
//!   shed with `ERR fault: backend lost`; the client never sees a
//!   silently-truncated or mixed stream.
//! * **Drain on SHUTDOWN** — the gateway stops accepting (late
//!   connections get an immediate `ERR busy`), lets replicas finish
//!   everything in flight, then exits.  Replicas are left running: a
//!   supervisor that wants them down sends them `SHUTDOWN` directly.
//!   (`SIGTERM` drains the same way when the supervisor maps it to the
//!   `SHUTDOWN` command — the process installs no signal handlers.)
//!
//! The deterministic chaos plan ([`ChaosPlan`], CLI `--chaos`) mirrors
//! the staged-read [`FaultPlan`](crate::sched::FaultPlan): seeded
//! probabilistic connect faults plus scripted per-backend triggers
//! (`kill`, `stall`, `slowaccept`) armed after a chosen number of routed
//! requests, so `tests/gateway_chaos.rs` can kill a chosen replica at a
//! chosen point and replay the identical run from the seed.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::health;
use super::router::{Pick, Router};
use crate::util::Rng;

// ---------------------------------------------------------------------
// Chaos plan (mirrors sched::fault::FaultPlan, but the unit is a backend
// replica instead of a checkpoint layer)
// ---------------------------------------------------------------------

/// What a chaos trigger does to gateway↔backend I/O.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosKind {
    /// Sever ALL gateway I/O to the backend, permanently: connects,
    /// request sends, and stream reads all fail immediately.  The
    /// replica process itself keeps running — this models a network
    /// partition or a crashed peer as the gateway experiences it.
    Kill,
    /// Sleep this many milliseconds before each request send (and model
    /// probes to the backend as timed out when the stall exceeds the
    /// probe timeout) — a slow, not dead, replica.
    Stall(u64),
    /// Sleep this many milliseconds before each connect to the backend —
    /// an accept loop that is alive but overloaded.
    SlowAccept(u64),
}

impl ChaosKind {
    fn parse(s: &str, stall_ms: u64) -> Result<Self> {
        match s {
            "kill" => Ok(ChaosKind::Kill),
            "stall" => Ok(ChaosKind::Stall(stall_ms)),
            "slowaccept" => Ok(ChaosKind::SlowAccept(stall_ms)),
            other => anyhow::bail!("unknown chaos kind '{other}' (kill|stall|slowaccept)"),
        }
    }
}

/// One scripted fault: backend index, kind, and how many times it fires
/// (`u32::MAX` = always; `kill` is permanent regardless).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosTrigger {
    /// Backend index (configuration order) the fault applies to.
    pub backend: usize,
    /// What happens.
    pub kind: ChaosKind,
    /// Remaining fires (`u32::MAX` = every time).
    pub times: u32,
}

/// Deterministic gateway chaos plan (CLI `--chaos`), same spec grammar
/// as `--inject-faults`:
/// `p=<prob>,seed=<u64>,stall_ms=<ms>,after=<n>,at=<backend>/<kind>[/<count|always>]`
/// with `kind` ∈ `kill|stall|slowaccept`.  `p` injects seeded transient
/// connect failures from the start; `at=` triggers arm only once
/// `after=` requests have been routed, so a replica can be killed at a
/// chosen *point in the workload* (request count, not wall clock — the
/// run replays identically from the seed).
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosPlan {
    /// Per-connect probability of a seeded transient failure.
    pub p: f64,
    /// RNG seed for the probabilistic faults.
    pub seed: u64,
    /// Default stall/slow-accept duration for triggers, in milliseconds.
    pub stall_ms: u64,
    /// Routed-request count at which `at=` triggers arm (0 = immediately).
    pub after: u64,
    /// Scripted per-backend faults.
    pub triggers: Vec<ChaosTrigger>,
}

impl Default for ChaosPlan {
    fn default() -> Self {
        ChaosPlan { p: 0.0, seed: 0x5eed, stall_ms: 50, after: 0, triggers: Vec::new() }
    }
}

impl ChaosPlan {
    /// Parse a comma-separated spec.  Scalar keys may appear in any
    /// order relative to `at=` triggers: triggers are resolved after all
    /// scalars so `at=0/stall,stall_ms=80` means an 80 ms stall.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = ChaosPlan::default();
        let mut raw_triggers: Vec<&str> = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) =
                part.split_once('=').with_context(|| format!("chaos spec '{part}': want k=v"))?;
            match key {
                "p" => plan.p = value.parse().with_context(|| format!("bad p '{value}'"))?,
                "seed" => {
                    plan.seed = value.parse().with_context(|| format!("bad seed '{value}'"))?
                }
                "stall_ms" => {
                    plan.stall_ms =
                        value.parse().with_context(|| format!("bad stall_ms '{value}'"))?
                }
                "after" => {
                    plan.after = value.parse().with_context(|| format!("bad after '{value}'"))?
                }
                "at" => raw_triggers.push(value),
                other => anyhow::bail!(
                    "unknown chaos spec key '{other}' (expected p|seed|stall_ms|after|at)"
                ),
            }
        }
        anyhow::ensure!((0.0..=1.0).contains(&plan.p), "p must be in [0, 1] (got {})", plan.p);
        for raw in raw_triggers {
            let parts: Vec<&str> = raw.split('/').collect();
            anyhow::ensure!(
                parts.len() == 2 || parts.len() == 3,
                "chaos trigger '{raw}': want <backend>/<kind>[/<count|always>]"
            );
            let backend: usize =
                parts[0].parse().with_context(|| format!("bad backend index '{}'", parts[0]))?;
            let kind = ChaosKind::parse(parts[1], plan.stall_ms)?;
            let times = match parts.get(2) {
                None => 1,
                Some(&"always") => u32::MAX,
                Some(n) => {
                    let n: u32 = n.parse().with_context(|| format!("bad count '{n}'"))?;
                    anyhow::ensure!(n >= 1, "trigger count must be >= 1");
                    n
                }
            };
            plan.triggers.push(ChaosTrigger { backend, kind, times });
        }
        Ok(plan)
    }

    /// True when the plan injects nothing (a passthrough).
    pub fn is_empty(&self) -> bool {
        self.p == 0.0 && self.triggers.is_empty()
    }
}

/// Runtime state of a [`ChaosPlan`]: the seeded RNG, per-trigger
/// remaining-fire counts, and the routed-request counter that arms the
/// scripted triggers.
pub struct ChaosInjector {
    plan: ChaosPlan,
    rng: Mutex<Rng>,
    fires: Mutex<Vec<u32>>,
    routed: AtomicU64,
}

impl ChaosInjector {
    /// Arm a plan.
    pub fn new(plan: ChaosPlan) -> Self {
        let fires = plan.triggers.iter().map(|t| t.times).collect();
        let rng = Mutex::new(Rng::new(plan.seed));
        ChaosInjector { plan, rng, fires: Mutex::new(fires), routed: AtomicU64::new(0) }
    }

    /// Count one routed request (arms `after=`-gated triggers).
    pub fn note_routed(&self) {
        self.routed.fetch_add(1, Ordering::SeqCst);
    }

    fn armed(&self) -> bool {
        self.routed.load(Ordering::SeqCst) >= self.plan.after
    }

    /// Is `bi` killed?  `kill` triggers are permanent once armed: every
    /// connect, send, and read to the backend fails until the process
    /// restarts (there is no un-kill).
    pub fn killed(&self, bi: usize) -> bool {
        self.armed()
            && self
                .plan
                .triggers
                .iter()
                .any(|t| t.backend == bi && t.kind == ChaosKind::Kill)
    }

    /// The `always`-scoped stall duration on `bi`, if armed — the prober
    /// models a stall past its timeout as a failed probe.
    pub fn always_stall_ms(&self, bi: usize) -> Option<u64> {
        if !self.armed() {
            return None;
        }
        self.plan.triggers.iter().find_map(|t| match t.kind {
            ChaosKind::Stall(ms) if t.backend == bi && t.times == u32::MAX => Some(ms),
            _ => None,
        })
    }

    /// Consume one fire of the first armed trigger on `bi` matching
    /// `want`, returning its duration.
    fn consume(&self, bi: usize, want: fn(ChaosKind) -> Option<u64>) -> Option<u64> {
        if !self.armed() {
            return None;
        }
        let mut fires = self.fires.lock().unwrap();
        for (ti, t) in self.plan.triggers.iter().enumerate() {
            if t.backend != bi || fires[ti] == 0 {
                continue;
            }
            if let Some(ms) = want(t.kind) {
                if fires[ti] != u32::MAX {
                    fires[ti] -= 1;
                }
                return Some(ms);
            }
        }
        None
    }

    /// Gate one connect to `bi`: killed backends fail, slow-accept
    /// triggers sleep, and the seeded `p` roll injects transient
    /// failures.
    pub fn on_connect(&self, bi: usize) -> Result<()> {
        anyhow::ensure!(!self.killed(bi), "chaos: backend {bi} killed");
        if let Some(ms) = self.consume(bi, |k| match k {
            ChaosKind::SlowAccept(ms) => Some(ms),
            _ => None,
        }) {
            std::thread::sleep(Duration::from_millis(ms));
        }
        if self.plan.p > 0.0 && self.rng.lock().unwrap().next_f64() < self.plan.p {
            anyhow::bail!("chaos: transient connect failure to backend {bi}");
        }
        Ok(())
    }

    /// Gate one request send to `bi`: killed backends fail, stall
    /// triggers sleep.
    pub fn on_send(&self, bi: usize) -> Result<()> {
        anyhow::ensure!(!self.killed(bi), "chaos: backend {bi} killed");
        if let Some(ms) = self.consume(bi, |k| match k {
            ChaosKind::Stall(ms) => Some(ms),
            _ => None,
        }) {
            std::thread::sleep(Duration::from_millis(ms));
        }
        Ok(())
    }

    /// Gate one stream read from `bi`: killed backends fail (this is how
    /// a kill severs an in-flight stream mid-generation).
    pub fn on_read(&self, bi: usize) -> Result<()> {
        anyhow::ensure!(!self.killed(bi), "chaos: backend {bi} killed");
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Gateway configuration and report
// ---------------------------------------------------------------------

/// Knobs of the gateway process (CLI `llamaf gateway`).
#[derive(Clone, Debug)]
pub struct GatewayOpts {
    /// Replica addresses, configuration order (`--backends a,b,c`).
    pub backends: Vec<String>,
    /// Gateway protocol worker threads.
    pub workers: usize,
    /// Pending client-connection queue bound; overflow is answered
    /// `ERR busy` at accept time.
    pub queue_depth: usize,
    /// Per-backend in-flight request bound (`--max-queue`): the bounded
    /// queue that propagates backpressure client → gateway → replica.
    pub max_queue: usize,
    /// Health-probe period, in milliseconds.
    pub probe_interval_ms: u64,
    /// Per-probe deadline (connect + write + read each), in milliseconds.
    pub probe_timeout_ms: u64,
    /// Backend connect deadline for request routing, in milliseconds.
    pub connect_timeout_ms: u64,
    /// Deterministic chaos plan (`--chaos`); None = no injection.
    pub chaos: Option<ChaosPlan>,
}

impl Default for GatewayOpts {
    fn default() -> Self {
        GatewayOpts {
            backends: Vec::new(),
            workers: 4,
            queue_depth: 64,
            max_queue: 8,
            probe_interval_ms: 50,
            probe_timeout_ms: 1000,
            connect_timeout_ms: 1000,
            chaos: None,
        }
    }
}

/// What a gateway run did (tests and the CLI summary).
#[derive(Clone, Copy, Debug)]
pub struct GatewayReport {
    /// Client connections taken by the accept loop (incl. rejected).
    pub accepted: usize,
    /// Requests routed to a backend (incl. ones later shed).
    pub routed: u64,
    /// Not-yet-started generations transparently re-routed off a failed
    /// backend.
    pub redirected: u64,
    /// In-flight streams shed with `ERR fault: backend lost`.
    pub shed: u64,
    /// Requests/connections refused with `ERR busy`.
    pub rejected: u64,
    /// Successful health probes.
    pub probes_ok: u64,
    /// Failed health probes.
    pub probes_failed: u64,
    /// Per-backend in-flight total at exit — 0 when the gateway's
    /// bounded queues drained (chaos tests pin this).
    pub in_flight_at_exit: usize,
    /// Client connections still queued at exit — 0 after a clean drain.
    pub queued_at_exit: usize,
}

/// State shared by the accept loop, the workers, and the prober.
struct GwShared {
    queue: Mutex<VecDeque<TcpStream>>,
    cv: Condvar,
    shutdown: AtomicBool,
    router: Router,
    chaos: Option<ChaosInjector>,
    workers_live: AtomicUsize,
    addr: SocketAddr,
    started: Instant,
    connect_timeout: Duration,
    probe_timeout: Duration,
    probe_interval: Duration,
    rejected: AtomicU64,
    queue_depth_gauge: AtomicUsize,
}

impl GwShared {
    /// Signal shutdown and unblock the workers and the accept loop (the
    /// latter by poking a throwaway connection at ourselves).
    fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.cv.notify_all();
        let _ = TcpStream::connect(self.addr);
    }
}

// ---------------------------------------------------------------------
// Backend connections (the sticky pin)
// ---------------------------------------------------------------------

/// One pinned gateway→replica connection (a replica-side session).
struct BackendConn {
    /// Backend index in the router table.
    bi: usize,
    write: TcpStream,
    read: BufReader<TcpStream>,
}

impl BackendConn {
    fn connect(shared: &GwShared, bi: usize) -> Result<BackendConn> {
        if let Some(c) = &shared.chaos {
            c.on_connect(bi)?;
        }
        let addr = shared.router.backends()[bi].addr;
        let stream = TcpStream::connect_timeout(&addr, shared.connect_timeout)
            .with_context(|| format!("connect backend {bi} ({addr})"))?;
        let read = BufReader::new(stream.try_clone()?);
        Ok(BackendConn { bi, write: stream, read })
    }

    fn send_line(&mut self, shared: &GwShared, line: &str) -> Result<()> {
        if let Some(c) = &shared.chaos {
            c.on_send(self.bi)?;
        }
        self.write.write_all(line.as_bytes())?;
        self.write.write_all(b"\n")?;
        self.write.flush()?;
        Ok(())
    }

    fn read_line(&mut self, shared: &GwShared) -> Result<String> {
        if let Some(c) = &shared.chaos {
            c.on_read(self.bi)?;
        }
        let mut line = String::new();
        let n = self.read.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "backend {} closed the connection", self.bi);
        Ok(line.trim_end().to_string())
    }
}

/// Why a proxied request failed.
enum ProxyFail {
    /// The backend failed before any reply line reached the client —
    /// safe to retry on another replica.
    NotStarted(anyhow::Error),
    /// The backend failed after output started — the client must be told
    /// (`ERR fault: backend lost`), never handed a truncated stream.
    MidStream(anyhow::Error),
    /// The *client* went away mid-stream; drop the pin so the replica
    /// sees the hangup and cancels the lane (no counters move).
    ClientGone,
}

// ---------------------------------------------------------------------
// The gateway
// ---------------------------------------------------------------------

/// A bound gateway listener (see the module docs for the topology).
pub struct Gateway {
    /// The bound listener the accept loop runs on.
    pub listener: TcpListener,
}

impl Gateway {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(Gateway { listener })
    }

    /// Address the listener actually bound (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Run the gateway until `SHUTDOWN` (or until `max_conns` client
    /// connections were taken; rejected ones count).  Returns the run's
    /// report once every worker and the prober have drained.
    pub fn run(&self, opts: &GatewayOpts, max_conns: Option<usize>) -> Result<GatewayReport> {
        anyhow::ensure!(opts.workers >= 1, "need at least one gateway worker");
        anyhow::ensure!(opts.queue_depth >= 1, "need a queue depth of at least 1");
        anyhow::ensure!(!opts.backends.is_empty(), "need at least one --backends address");
        let mut addrs = Vec::with_capacity(opts.backends.len());
        for b in &opts.backends {
            let addr = b
                .to_socket_addrs()
                .with_context(|| format!("resolve backend '{b}'"))?
                .next()
                .with_context(|| format!("backend '{b}' resolved to no address"))?;
            addrs.push(addr);
        }
        let shared = GwShared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            router: Router::new(addrs, opts.max_queue),
            chaos: opts.chaos.clone().map(ChaosInjector::new),
            // pre-counted (decrement-only) so a SHUTDOWN racing worker
            // startup can't observe 0 and skip the drain loop below
            workers_live: AtomicUsize::new(opts.workers),
            addr: self.local_addr()?,
            started: Instant::now(),
            connect_timeout: Duration::from_millis(opts.connect_timeout_ms.max(1)),
            probe_timeout: Duration::from_millis(opts.probe_timeout_ms.max(1)),
            probe_interval: Duration::from_millis(opts.probe_interval_ms.max(1)),
            rejected: AtomicU64::new(0),
            queue_depth_gauge: AtomicUsize::new(0),
        };
        let mut accepted = 0usize;

        std::thread::scope(|scope| -> Result<()> {
            for wi in 0..opts.workers {
                let shared = &shared;
                std::thread::Builder::new()
                    .name(format!("llamaf-gw-{wi}"))
                    .spawn_scoped(scope, move || {
                        while let Some(conn) = next_client(shared) {
                            if let Err(e) = handle_client(conn, shared) {
                                eprintln!("llamaf-gw-{wi}: connection error: {e:#}");
                            }
                        }
                        shared.workers_live.fetch_sub(1, Ordering::SeqCst);
                    })
                    .expect("spawn gateway worker");
            }
            {
                let shared = &shared;
                std::thread::Builder::new()
                    .name("llamaf-gw-probe".into())
                    .spawn_scoped(scope, move || prober_loop(shared))
                    .expect("spawn gateway prober");
            }

            for stream in self.listener.incoming() {
                if shared.shutdown.load(Ordering::SeqCst) {
                    // usually the shutdown self-poke (already closed; the
                    // write fails harmlessly), possibly a racing client:
                    // refuse it honestly either way
                    if let Ok(mut s) = stream {
                        let _ = s.write_all(b"ERR busy: gateway shutting down\n");
                    }
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                accepted += 1;
                let mut q = shared.queue.lock().unwrap();
                if q.len() >= opts.queue_depth {
                    drop(q);
                    shared.rejected.fetch_add(1, Ordering::Relaxed);
                    let mut s = stream;
                    let _ = s.write_all(b"ERR busy: connection queue full\n");
                    let _ = s.flush();
                } else {
                    q.push_back(stream);
                    shared.queue_depth_gauge.store(q.len(), Ordering::Relaxed);
                    shared.cv.notify_one();
                }
                if let Some(max) = max_conns {
                    if accepted >= max {
                        break;
                    }
                }
            }
            // Drain: stop admitting first, then let workers finish what
            // is queued.  Late connections are refused immediately (same
            // contract as the engine server's drain).
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
            self.listener.set_nonblocking(true)?;
            while shared.workers_live.load(Ordering::SeqCst) > 0 {
                match self.listener.accept() {
                    Ok((mut s, _)) => {
                        let _ = s.write_all(b"ERR busy: gateway shutting down\n");
                        let _ = s.flush();
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            let _ = self.listener.set_nonblocking(false);
            Ok(())
        })?;

        let queued_at_exit = shared.queue.lock().unwrap().len();
        Ok(GatewayReport {
            accepted,
            routed: shared.router.routed_total(),
            redirected: shared.router.redirected(),
            shed: shared.router.shed(),
            rejected: shared.rejected.load(Ordering::Relaxed) + shared.router.busy_rejected(),
            probes_ok: shared.router.probes_ok(),
            probes_failed: shared.router.probes_failed(),
            in_flight_at_exit: shared.router.in_flight_total(),
            queued_at_exit,
        })
    }
}

/// Pop the next queued client connection, or None when shut down and
/// drained.
fn next_client(shared: &GwShared) -> Option<TcpStream> {
    let mut q = shared.queue.lock().unwrap();
    loop {
        if let Some(conn) = q.pop_front() {
            shared.queue_depth_gauge.store(q.len(), Ordering::Relaxed);
            return Some(conn);
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return None;
        }
        q = shared.cv.wait(q).unwrap();
    }
}

/// Health-prober body: probe every backend once per interval until
/// shutdown.  A killed backend fails its probe; an `always`-stalled one
/// whose stall exceeds the probe timeout counts as timed out.
fn prober_loop(shared: &GwShared) {
    while !shared.shutdown.load(Ordering::SeqCst) {
        for (bi, b) in shared.router.backends().iter().enumerate() {
            let busy = probe_backend(shared, bi, b.addr);
            shared.router.note_probe(bi, busy);
        }
        // sleep in slices so SHUTDOWN is prompt even at long intervals
        let mut left = shared.probe_interval;
        while !left.is_zero() && !shared.shutdown.load(Ordering::SeqCst) {
            let slice = left.min(Duration::from_millis(5));
            std::thread::sleep(slice);
            left -= slice;
        }
    }
}

/// One chaos-aware probe: Some(busy) on success, None on failure.
fn probe_backend(shared: &GwShared, bi: usize, addr: SocketAddr) -> Option<u64> {
    if let Some(c) = &shared.chaos {
        if c.killed(bi) {
            return None;
        }
        if let Some(ms) = c.always_stall_ms(bi) {
            if Duration::from_millis(ms) >= shared.probe_timeout {
                return None; // stalled past the deadline == timed out
            }
        }
    }
    health::probe(addr, shared.probe_timeout).ok().map(|r| r.busy)
}

/// Serve one client connection: local commands answered in place,
/// generations proxied through the sticky backend pin.
fn handle_client(stream: TcpStream, shared: &GwShared) -> Result<()> {
    let mut out = stream.try_clone()?;
    let reader = BufReader::new(stream);
    let mut pinned: Option<BackendConn> = None;

    let mut result = Ok(());
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(e) => {
                result = Err(e.into());
                break;
            }
        };
        let line = line.trim().to_string();
        if line == "QUIT" {
            break;
        }
        let reply = gw_command(&line, shared, &mut pinned, &mut out);
        match reply {
            Ok(Some(r)) => {
                if out.write_all(r.as_bytes()).and_then(|_| out.write_all(b"\n")).is_err() {
                    break; // client went away mid-reply
                }
                let _ = out.flush();
            }
            Ok(None) => {} // streaming command wrote its own lines
            Err(e) => {
                let _ = out.write_all(format!("ERR {e}\n").as_bytes());
                let _ = out.flush();
            }
        }
        if line == "SHUTDOWN" {
            break;
        }
    }
    // dropping the pin closes the replica connection, which releases the
    // replica-side session (and cancels any lane the client abandoned)
    drop(pinned);
    result
}

/// Execute one client command.  `Ok(Some(reply))` for one-line replies,
/// `Ok(None)` when the command streamed its own output.
fn gw_command(
    line: &str,
    shared: &GwShared,
    pinned: &mut Option<BackendConn>,
    out: &mut TcpStream,
) -> Result<Option<String>> {
    if line == "PING" {
        return Ok(Some("PONG".into()));
    }
    if line == "SHUTDOWN" {
        shared.begin_shutdown();
        return Ok(Some("OK shutting down".into()));
    }
    if line == "HEALTH" {
        let (up, _degraded, _down) = shared.router.state_counts();
        return Ok(Some(format!(
            "OK up={} busy={} lanes={up}",
            shared.started.elapsed().as_secs(),
            shared.router.in_flight_total(),
        )));
    }
    if line == "STATS" {
        return Ok(Some(gateway_stats(shared)));
    }
    if line == "METRICS" {
        let lines = gateway_metrics(shared);
        out.write_all(format!("METRICS {}\n", lines.len()).as_bytes())?;
        for (name, value) in lines {
            out.write_all(format!("llamaf_gateway_{name} {value}\n").as_bytes())?;
        }
        out.flush()?;
        return Ok(None);
    }
    if line == "TRACE" {
        // per-request trace state lives on the replica that served the
        // generation — exactly the sticky pin
        let bc = pinned
            .as_mut()
            .context("no completed generation on this connection (run GEN/SGEN first)")?;
        let relayed = bc.send_line(shared, "TRACE").and_then(|_| bc.read_line(shared));
        return match relayed {
            Ok(reply) => Ok(Some(reply)),
            Err(e) => {
                *pinned = None; // conn state unknown; re-pin next request
                Err(e)
            }
        };
    }
    if line.starts_with("SGEN ") || line.starts_with("GEN ") {
        route_generation(line, shared, pinned, out)?;
        return Ok(None);
    }
    anyhow::bail!("unknown command (GEN/SGEN/STATS/TRACE/METRICS/PING/HEALTH/SHUTDOWN/QUIT)")
}

/// Route one generation: pin a backend (least-loaded, retrying the
/// connect on other replicas), enforce the bounded per-backend queue,
/// proxy the stream, and redirect or shed on failure per the module
/// contract.  Writes every client-visible line itself.
fn route_generation(
    line: &str,
    shared: &GwShared,
    pinned: &mut Option<BackendConn>,
    out: &mut TcpStream,
) -> Result<()> {
    let streaming = line.starts_with("SGEN ");
    let mut tried: Vec<usize> = Vec::new();
    let mut redirected = false;
    loop {
        let fresh_pin = pinned.is_none();
        if fresh_pin {
            match pin_backend(shared, &mut tried) {
                Ok(bc) => {
                    if redirected {
                        shared.router.note_redirected();
                        redirected = false;
                    }
                    *pinned = Some(bc);
                }
                Err(Pick::Saturated) => {
                    shared.router.note_busy_rejected();
                    out.write_all(b"ERR busy: all backends at their queue bound\n")?;
                    out.flush()?;
                    return Ok(());
                }
                Err(_) => {
                    out.write_all(b"ERR fault: no backend available\n")?;
                    out.flush()?;
                    return Ok(());
                }
            }
        }
        let bc = pinned.as_mut().expect("pinned above");
        let bi = bc.bi;
        if !shared.router.admit(bi) {
            if fresh_pin {
                // lost the race between pick's load check and admit; the
                // pin carries no session state yet, so try another replica
                *pinned = None;
                tried.push(bi);
                continue;
            }
            // the sticky replica is at its bound; stealing another
            // replica's KV would break stickiness, so shed honestly
            shared.router.note_busy_rejected();
            out.write_all(b"ERR busy: backend queue full\n")?;
            out.flush()?;
            return Ok(());
        }
        shared.router.note_routed(bi);
        if let Some(c) = &shared.chaos {
            c.note_routed();
        }
        let proxied = proxy_request(bc, shared, line, streaming, out);
        shared.router.release(bi);
        match proxied {
            Ok(()) => return Ok(()),
            Err(ProxyFail::ClientGone) => {
                *pinned = None;
                return Ok(());
            }
            Err(ProxyFail::NotStarted(e)) => {
                // replica died before the client saw anything: redirect
                eprintln!("llamaf-gw: backend {bi} failed pre-stream, redirecting: {e:#}");
                shared.router.note_backend_failure(bi);
                *pinned = None;
                tried.push(bi);
                redirected = true;
                continue;
            }
            Err(ProxyFail::MidStream(e)) => {
                eprintln!("llamaf-gw: backend {bi} lost mid-stream: {e:#}");
                shared.router.note_backend_failure(bi);
                shared.router.note_shed();
                *pinned = None;
                let _ = out.write_all(b"ERR fault: backend lost\n");
                let _ = out.flush();
                return Ok(());
            }
        }
    }
}

/// Pick and connect a backend for a fresh pin, excluding (and extending)
/// `tried` as connects fail.  `Err` carries the final [`Pick`] verdict.
fn pin_backend(shared: &GwShared, tried: &mut Vec<usize>) -> Result<BackendConn, Pick> {
    loop {
        let bi = match shared.router.pick(tried) {
            Pick::Backend(bi) => bi,
            verdict => return Err(verdict),
        };
        match BackendConn::connect(shared, bi) {
            Ok(bc) => return Ok(bc),
            Err(_) => {
                shared.router.note_backend_failure(bi);
                tried.push(bi);
            }
        }
    }
}

/// Forward one generation request over the pin and relay the reply
/// stream.  Terminal lines: `DONE`/`OK`/`ERR` (forwarded verbatim — a
/// replica's own `ERR busy`/`ERR fault`/`ERR deadline` stays honest
/// end-to-end).
fn proxy_request(
    bc: &mut BackendConn,
    shared: &GwShared,
    line: &str,
    streaming: bool,
    out: &mut TcpStream,
) -> Result<(), ProxyFail> {
    if let Err(e) = bc.send_line(shared, line) {
        return Err(ProxyFail::NotStarted(e));
    }
    let mut forwarded = false;
    loop {
        let reply = match bc.read_line(shared) {
            Ok(r) => r,
            Err(e) if forwarded => return Err(ProxyFail::MidStream(e)),
            Err(e) => return Err(ProxyFail::NotStarted(e)),
        };
        if out
            .write_all(reply.as_bytes())
            .and_then(|_| out.write_all(b"\n"))
            .and_then(|_| out.flush())
            .is_err()
        {
            return Err(ProxyFail::ClientGone);
        }
        forwarded = true;
        let terminal = if streaming {
            reply.starts_with("DONE ") || reply.starts_with("ERR ")
        } else {
            true // GEN replies are a single OK/ERR line
        };
        if terminal {
            return Ok(());
        }
    }
}

/// The gateway's one-line `STATS` reply: aggregate counters plus a
/// `b<i>=<state>/<in_flight>/<routed>` token per backend.
fn gateway_stats(shared: &GwShared) -> String {
    let (up, degraded, down) = shared.router.state_counts();
    let mut s = format!(
        "OK gateway backends={} up={up} degraded={degraded} down={down} routed={} \
         redirected={} shed={} busy_rejected={} queue_depth={} in_flight={} probes_ok={} \
         probes_failed={}",
        shared.router.backends().len(),
        shared.router.routed_total(),
        shared.router.redirected(),
        shared.router.shed(),
        shared.rejected.load(Ordering::Relaxed) + shared.router.busy_rejected(),
        shared.queue_depth_gauge.load(Ordering::Relaxed),
        shared.router.in_flight_total(),
        shared.router.probes_ok(),
        shared.router.probes_failed(),
    );
    for (bi, b) in shared.router.backends().iter().enumerate() {
        s.push_str(&format!(" b{bi}={}/{}/{}", b.state().label(), b.in_flight(), b.routed()));
    }
    s
}

/// The gateway's `METRICS` export (names get the `llamaf_gateway_`
/// prefix): 12 aggregate lines plus 4 per backend, in table order.
fn gateway_metrics(shared: &GwShared) -> Vec<(String, String)> {
    let (u, d, n) = shared.router.state_counts();
    let r = &shared.router;
    let mut lines: Vec<(String, String)> = vec![
        ("backends".into(), r.backends().len().to_string()),
        ("backends_up".into(), u.to_string()),
        ("backends_degraded".into(), d.to_string()),
        ("backends_down".into(), n.to_string()),
        ("routed_total".into(), r.routed_total().to_string()),
        ("redirected_total".into(), r.redirected().to_string()),
        ("shed_total".into(), r.shed().to_string()),
        (
            "rejected_total".into(),
            (shared.rejected.load(Ordering::Relaxed) + r.busy_rejected()).to_string(),
        ),
        ("queue_depth".into(), shared.queue_depth_gauge.load(Ordering::Relaxed).to_string()),
        ("in_flight".into(), r.in_flight_total().to_string()),
        ("probes_ok_total".into(), r.probes_ok().to_string()),
        ("probes_failed_total".into(), r.probes_failed().to_string()),
    ];
    for (bi, b) in r.backends().iter().enumerate() {
        let state_num = match b.state().label() {
            "up" => 2,
            "degraded" => 1,
            _ => 0,
        };
        lines.push((format!("backend{bi}_state"), state_num.to_string()));
        lines.push((format!("backend{bi}_in_flight"), b.in_flight().to_string()));
        lines.push((format!("backend{bi}_routed"), b.routed().to_string()));
        lines.push((format!("backend{bi}_probe_busy"), b.probe_busy().to_string()));
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_plan_parses_like_a_fault_plan() {
        let p = ChaosPlan::parse("p=0.25,seed=7,stall_ms=80,after=4,at=1/kill").unwrap();
        assert_eq!(p.p, 0.25);
        assert_eq!(p.seed, 7);
        assert_eq!(p.after, 4);
        assert_eq!(
            p.triggers,
            vec![ChaosTrigger { backend: 1, kind: ChaosKind::Kill, times: 1 }]
        );
        // triggers resolve after scalars regardless of spec order
        let p = ChaosPlan::parse("at=0/stall,stall_ms=80").unwrap();
        assert_eq!(p.triggers[0].kind, ChaosKind::Stall(80));
        let p = ChaosPlan::parse("at=2/slowaccept/always").unwrap();
        assert_eq!(p.triggers[0].times, u32::MAX);
        assert_eq!(p.triggers[0].kind, ChaosKind::SlowAccept(50));
        let p = ChaosPlan::parse("at=0/stall/3").unwrap();
        assert_eq!(p.triggers[0].times, 3);
        assert!(ChaosPlan::parse("").unwrap().is_empty());
        assert!(ChaosPlan::parse("bogus=1").is_err());
        assert!(ChaosPlan::parse("at=0/explode").is_err());
        assert!(ChaosPlan::parse("at=0").is_err());
        assert!(ChaosPlan::parse("p=1.5").is_err());
        assert!(ChaosPlan::parse("at=0/stall/0").is_err());
    }

    #[test]
    fn kill_is_permanent_and_gated_by_after() {
        let inj = ChaosInjector::new(ChaosPlan::parse("after=2,at=1/kill").unwrap());
        assert!(!inj.killed(1), "not armed before `after` requests routed");
        assert!(inj.on_connect(1).is_ok());
        inj.note_routed();
        inj.note_routed();
        assert!(inj.killed(1), "armed at the request-count mark");
        assert!(!inj.killed(0), "only the targeted backend");
        assert!(inj.on_connect(1).is_err());
        assert!(inj.on_send(1).is_err());
        assert!(inj.on_read(1).is_err());
        assert!(inj.on_read(1).is_err(), "kill never heals");
        assert!(inj.on_connect(0).is_ok());
    }

    #[test]
    fn counted_stalls_consume_fires_and_always_does_not() {
        let inj = ChaosInjector::new(ChaosPlan::parse("stall_ms=0,at=0/stall/2").unwrap());
        assert!(inj.on_send(0).is_ok()); // fire 1 (0 ms: no real sleep)
        assert!(inj.on_send(0).is_ok()); // fire 2
        assert_eq!(inj.consume(0, |k| matches!(k, ChaosKind::Stall(_)).then_some(0)), None);
        let inj = ChaosInjector::new(ChaosPlan::parse("stall_ms=7,at=0/stall/always").unwrap());
        assert_eq!(inj.always_stall_ms(0), Some(7));
        assert_eq!(inj.always_stall_ms(1), None);
    }

    #[test]
    fn seeded_connect_faults_replay_identically() {
        let run = |seed: u64| -> Vec<bool> {
            let inj =
                ChaosInjector::new(ChaosPlan::parse(&format!("p=0.5,seed={seed}")).unwrap());
            (0..32).map(|_| inj.on_connect(0).is_ok()).collect()
        };
        assert_eq!(run(9), run(9), "same seed, same fault sequence");
        assert_ne!(run(9), run(10), "different seed, different sequence");
        let faults = run(9).iter().filter(|ok| !**ok).count();
        assert!(faults > 0, "p=0.5 over 32 rolls injects something");
    }
}
