//! Health-aware, least-loaded routing table for the serving gateway.
//!
//! The [`Router`] owns one [`Backend`] entry per engine replica: its
//! address, its prober-fed [`HealthTracker`], a bounded in-flight
//! request count (the per-backend queue that propagates backpressure
//! client → gateway → replica), and the routed/probe counters exported
//! through the gateway's `STATS`/`METRICS`.
//!
//! Routing policy ([`Router::pick`]): among backends that are not
//! excluded, not `Down`, and not at their in-flight bound, choose the
//! least-loaded one, preferring `Up` over `Degraded`.  Session
//! stickiness is the *gateway's* job (one pinned replica connection per
//! client connection); the router only decides where a session starts —
//! and where it restarts after a redirect.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::health::{BackendState, HealthTracker};

/// One engine replica as the gateway sees it.
pub struct Backend {
    /// Replica address the gateway connects to.
    pub addr: SocketAddr,
    health: Mutex<HealthTracker>,
    in_flight: AtomicUsize,
    routed: AtomicU64,
    /// `busy=` gauge from the replica's last successful `HEALTH` probe.
    probe_busy: AtomicU64,
}

impl Backend {
    fn new(addr: SocketAddr) -> Self {
        Backend {
            addr,
            health: Mutex::new(HealthTracker::default()),
            in_flight: AtomicUsize::new(0),
            routed: AtomicU64::new(0),
            probe_busy: AtomicU64::new(0),
        }
    }

    /// Current health state (prober-fed).
    pub fn state(&self) -> BackendState {
        self.health.lock().unwrap().state()
    }

    /// Requests currently in flight on this backend through the gateway.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Requests ever routed to this backend.
    pub fn routed(&self) -> u64 {
        self.routed.load(Ordering::Relaxed)
    }

    /// `busy=` sessions reported by the last successful probe.
    pub fn probe_busy(&self) -> u64 {
        self.probe_busy.load(Ordering::Relaxed)
    }
}

/// Outcome of a routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pick {
    /// Route to this backend index.
    Backend(usize),
    /// At least one backend is alive, but every alive one is at its
    /// in-flight bound — answer `ERR busy` (backpressure, not failure).
    Saturated,
    /// Every backend is `Down` or excluded — answer `ERR fault`.
    NoneAlive,
}

/// Routing table plus the gateway-level counters.
pub struct Router {
    backends: Vec<Backend>,
    /// Per-backend in-flight bound (CLI `--max-queue`).
    pub max_queue: usize,
    redirected: AtomicU64,
    shed: AtomicU64,
    busy_rejected: AtomicU64,
    probes_ok: AtomicU64,
    probes_failed: AtomicU64,
}

impl Router {
    /// Build a table over `addrs` with a per-backend in-flight bound of
    /// `max_queue` (clamped to ≥ 1).
    pub fn new(addrs: Vec<SocketAddr>, max_queue: usize) -> Self {
        Router {
            backends: addrs.into_iter().map(Backend::new).collect(),
            max_queue: max_queue.max(1),
            redirected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            busy_rejected: AtomicU64::new(0),
            probes_ok: AtomicU64::new(0),
            probes_failed: AtomicU64::new(0),
        }
    }

    /// The replica table, in configuration order.
    pub fn backends(&self) -> &[Backend] {
        &self.backends
    }

    /// Least-loaded routing decision, skipping `exclude`d indices (the
    /// backends already tried for this request).  `Up` beats `Degraded`
    /// at any load; `Down` and at-bound backends are never picked.
    pub fn pick(&self, exclude: &[usize]) -> Pick {
        let mut best: Option<(u8, usize, usize)> = None; // (state rank, load, index)
        let mut any_alive = false;
        for (bi, b) in self.backends.iter().enumerate() {
            if exclude.contains(&bi) {
                continue;
            }
            let rank = match b.state() {
                BackendState::Up => 0u8,
                BackendState::Degraded => 1,
                BackendState::Down => continue,
            };
            any_alive = true;
            let load = b.in_flight();
            if load >= self.max_queue {
                continue; // at bound: backpressure, look elsewhere
            }
            if best.map(|(r, l, _)| (rank, load) < (r, l)).unwrap_or(true) {
                best = Some((rank, load, bi));
            }
        }
        match best {
            Some((_, _, bi)) => Pick::Backend(bi),
            None if any_alive => Pick::Saturated,
            None => Pick::NoneAlive,
        }
    }

    /// Reserve one in-flight slot on `bi` (bounded by
    /// [`Router::max_queue`]).  Returns false when the backend is already
    /// at its bound — the caller re-picks or sheds with `ERR busy`.
    pub fn admit(&self, bi: usize) -> bool {
        self.backends[bi]
            .in_flight
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                (n < self.max_queue).then_some(n + 1)
            })
            .is_ok()
    }

    /// Release the in-flight slot taken by [`Router::admit`].
    pub fn release(&self, bi: usize) {
        let prev = self.backends[bi].in_flight.fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev > 0, "release without admit on backend {bi}");
    }

    /// Count one request routed to `bi`.
    pub fn note_routed(&self, bi: usize) {
        self.backends[bi].routed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one not-yet-started generation redirected off a failed
    /// backend.
    pub fn note_redirected(&self) {
        self.redirected.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one in-flight stream shed with `ERR fault: backend lost`.
    pub fn note_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request refused with `ERR busy` (all alive backends at
    /// their bound).
    pub fn note_busy_rejected(&self) {
        self.busy_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Feed one probe outcome for `bi` into its tracker and the probe
    /// counters; a success carries the replica's `busy=` gauge.
    pub fn note_probe(&self, bi: usize, busy: Option<u64>) {
        let mut h = self.backends[bi].health.lock().unwrap();
        match busy {
            Some(n) => {
                h.record_success();
                self.backends[bi].probe_busy.store(n, Ordering::Relaxed);
                self.probes_ok.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                h.record_failure();
                self.probes_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Record one observed request-path failure (a failed connect or a
    /// dead stream) against `bi`'s health — demotes `Up` → `Degraded`
    /// immediately so new sessions prefer other replicas; the prober
    /// escalates to `Down` (or restores `Up`) within its next intervals.
    pub fn note_backend_failure(&self, bi: usize) {
        self.backends[bi].health.lock().unwrap().record_failure();
    }

    /// Force `bi` down as if [`HealthTracker::down_after`] probes failed
    /// — the routing fast path for an observed hard connection failure,
    /// so new sessions stop picking a dead replica before the prober
    /// confirms it.
    pub fn mark_down(&self, bi: usize) {
        let mut h = self.backends[bi].health.lock().unwrap();
        for _ in 0..h.down_after {
            h.record_failure();
        }
    }

    /// Backend counts by state: `(up, degraded, down)`.
    pub fn state_counts(&self) -> (usize, usize, usize) {
        let mut counts = (0, 0, 0);
        for b in &self.backends {
            match b.state() {
                BackendState::Up => counts.0 += 1,
                BackendState::Degraded => counts.1 += 1,
                BackendState::Down => counts.2 += 1,
            }
        }
        counts
    }

    /// Total requests routed (sum over backends).
    pub fn routed_total(&self) -> u64 {
        self.backends.iter().map(|b| b.routed()).sum()
    }

    /// Total in-flight requests (sum over backends).
    pub fn in_flight_total(&self) -> usize {
        self.backends.iter().map(|b| b.in_flight()).sum()
    }

    /// Redirected-generation counter.
    pub fn redirected(&self) -> u64 {
        self.redirected.load(Ordering::Relaxed)
    }

    /// Shed-stream counter.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Busy-rejection counter.
    pub fn busy_rejected(&self) -> u64 {
        self.busy_rejected.load(Ordering::Relaxed)
    }

    /// Successful-probe counter.
    pub fn probes_ok(&self) -> u64 {
        self.probes_ok.load(Ordering::Relaxed)
    }

    /// Failed-probe counter.
    pub fn probes_failed(&self) -> u64 {
        self.probes_failed.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<SocketAddr> {
        (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i).parse().unwrap()).collect()
    }

    #[test]
    fn pick_is_least_loaded_and_sticky_free() {
        let r = Router::new(addrs(3), 4);
        assert!(r.admit(0));
        assert!(r.admit(0));
        assert!(r.admit(1));
        // loads: [2, 1, 0] -> backend 2
        assert_eq!(r.pick(&[]), Pick::Backend(2));
        assert!(r.admit(2));
        assert!(r.admit(2));
        // loads: [2, 1, 2] -> backend 1
        assert_eq!(r.pick(&[]), Pick::Backend(1));
        // excluding 1 -> tie between 0 and 2 broken by index order
        assert_eq!(r.pick(&[1]), Pick::Backend(0));
    }

    #[test]
    fn up_beats_degraded_at_any_load() {
        let r = Router::new(addrs(2), 8);
        r.note_probe(0, None); // backend 0 degraded
        assert!(r.admit(1));
        assert!(r.admit(1));
        // degraded 0 is empty, up 1 carries load: up still wins
        assert_eq!(r.pick(&[]), Pick::Backend(1));
        // ...until up is excluded; degraded remains routable
        assert_eq!(r.pick(&[1]), Pick::Backend(0));
    }

    #[test]
    fn down_backends_are_never_picked() {
        let r = Router::new(addrs(2), 8);
        r.mark_down(0);
        assert_eq!(r.backends()[0].state(), BackendState::Down);
        assert_eq!(r.pick(&[]), Pick::Backend(1));
        r.mark_down(1);
        assert_eq!(r.pick(&[]), Pick::NoneAlive);
        // recovery: one good probe restores routability
        r.note_probe(0, Some(2));
        assert_eq!(r.pick(&[]), Pick::Backend(0));
        assert_eq!(r.backends()[0].probe_busy(), 2);
        assert_eq!(r.state_counts(), (1, 0, 1));
    }

    #[test]
    fn bounded_admission_saturates_honestly() {
        let r = Router::new(addrs(2), 2);
        for bi in 0..2 {
            assert!(r.admit(bi));
            assert!(r.admit(bi));
            assert!(!r.admit(bi), "bound is {}", r.max_queue);
        }
        assert_eq!(r.pick(&[]), Pick::Saturated, "alive but full != dead");
        r.release(0);
        assert_eq!(r.pick(&[]), Pick::Backend(0));
        assert_eq!(r.in_flight_total(), 3);
    }

    #[test]
    fn max_queue_is_clamped_to_at_least_one() {
        let r = Router::new(addrs(1), 0);
        assert!(r.admit(0), "clamped bound still admits one");
        assert!(!r.admit(0));
    }
}
