//! Per-backend health probing for the serving gateway.
//!
//! Two halves, kept separate so each is testable alone:
//!
//! * [`HealthTracker`] — a pure `Up`/`Degraded`/`Down` state machine fed
//!   probe outcomes.  One failed probe demotes `Up` → `Degraded` (the
//!   backend stays routable as a last resort); [`HealthTracker::down_after`]
//!   consecutive failures demote to `Down` (never routed); any success
//!   restores `Up` immediately.
//! * [`probe`] — one wire probe: connect with a deadline, send the
//!   engine server's one-line `HEALTH` command, parse
//!   `OK up=<s> busy=<n> lanes=<n>`.  Every step is bounded by the
//!   timeout, so a stalled backend costs the prober one timeout, never a
//!   hang.

use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{Context, Result};

/// Routability of one backend as seen by the gateway's prober.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendState {
    /// Last probe succeeded: preferred routing target.
    Up,
    /// At least one recent probe failed (but fewer than
    /// [`HealthTracker::down_after`] in a row): routed only when no `Up`
    /// backend can take the request.
    Degraded,
    /// [`HealthTracker::down_after`] consecutive probes failed: never
    /// routed until a probe succeeds again.
    Down,
}

impl BackendState {
    /// Lower-case label used in `STATS` replies (`up`/`degraded`/`down`).
    pub fn label(self) -> &'static str {
        match self {
            BackendState::Up => "up",
            BackendState::Degraded => "degraded",
            BackendState::Down => "down",
        }
    }
}

/// Pure probe-outcome state machine (no I/O, no clock): feed it
/// [`HealthTracker::record_success`] / [`HealthTracker::record_failure`]
/// and read [`HealthTracker::state`].
#[derive(Clone, Copy, Debug)]
pub struct HealthTracker {
    /// Consecutive probe failures that demote `Degraded` → `Down`.
    pub down_after: u32,
    failures: u32,
    state: BackendState,
}

/// Default consecutive-failure threshold for `Down`.
pub const DEFAULT_DOWN_AFTER: u32 = 3;

impl Default for HealthTracker {
    fn default() -> Self {
        HealthTracker::new(DEFAULT_DOWN_AFTER)
    }
}

impl HealthTracker {
    /// Fresh tracker, optimistically `Up` (a gateway can route before the
    /// first probe completes; the prober demotes liars within one
    /// interval).
    pub fn new(down_after: u32) -> Self {
        HealthTracker { down_after: down_after.max(1), failures: 0, state: BackendState::Up }
    }

    /// A probe succeeded: back to `Up`, failure streak reset.
    pub fn record_success(&mut self) {
        self.failures = 0;
        self.state = BackendState::Up;
    }

    /// A probe failed (connect error, timeout, malformed reply).
    pub fn record_failure(&mut self) {
        self.failures = self.failures.saturating_add(1);
        self.state = if self.failures >= self.down_after {
            BackendState::Down
        } else {
            BackendState::Degraded
        };
    }

    /// Current routability.
    pub fn state(&self) -> BackendState {
        self.state
    }

    /// Consecutive failures recorded since the last success.
    pub fn failures(&self) -> u32 {
        self.failures
    }
}

/// Parsed fields of an engine server's `HEALTH` reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProbeReply {
    /// Backend uptime in whole seconds.
    pub up_s: u64,
    /// Sessions currently checked out (busy) on the backend.
    pub busy: u64,
    /// The backend's lane capacity per batched step (`--max-batch`).
    pub lanes: u64,
}

/// Parse `OK up=<s> busy=<n> lanes=<n>` (the engine server's `HEALTH`
/// reply).  Strict: every field must be present and numeric, so a
/// half-written reply from a dying backend counts as a failed probe.
pub fn parse_health_reply(line: &str) -> Result<ProbeReply> {
    let rest = line
        .trim()
        .strip_prefix("OK ")
        .with_context(|| format!("HEALTH reply not OK: {line:?}"))?;
    let mut up_s = None;
    let mut busy = None;
    let mut lanes = None;
    for field in rest.split_whitespace() {
        let (key, value) = field
            .split_once('=')
            .with_context(|| format!("malformed HEALTH field {field:?}"))?;
        let value: u64 =
            value.parse().with_context(|| format!("non-numeric HEALTH field {field:?}"))?;
        match key {
            "up" => up_s = Some(value),
            "busy" => busy = Some(value),
            "lanes" => lanes = Some(value),
            _ => {} // forward-compatible: unknown fields are ignored
        }
    }
    Ok(ProbeReply {
        up_s: up_s.context("HEALTH reply missing up=")?,
        busy: busy.context("HEALTH reply missing busy=")?,
        lanes: lanes.context("HEALTH reply missing lanes=")?,
    })
}

/// One wire probe of `addr`: connect, send `HEALTH`, read and parse the
/// one-line reply.  Connect, write, and read are all bounded by
/// `timeout` — a stalled backend surfaces as an error within ~3×
/// `timeout` worst case, never a hang.
pub fn probe(addr: SocketAddr, timeout: Duration) -> Result<ProbeReply> {
    use std::io::{BufRead, BufReader, Write};
    let mut conn = TcpStream::connect_timeout(&addr, timeout)
        .with_context(|| format!("probe connect {addr}"))?;
    conn.set_read_timeout(Some(timeout))?;
    conn.set_write_timeout(Some(timeout))?;
    conn.write_all(b"HEALTH\n").with_context(|| format!("probe write {addr}"))?;
    let mut reader = BufReader::new(conn.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line).with_context(|| format!("probe read {addr}"))?;
    anyhow::ensure!(!line.is_empty(), "probe {addr}: connection closed before reply");
    let reply = parse_health_reply(&line)?;
    let _ = conn.write_all(b"QUIT\n");
    Ok(reply)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_walks_up_degraded_down_and_recovers() {
        let mut t = HealthTracker::new(3);
        assert_eq!(t.state(), BackendState::Up, "optimistic start");
        t.record_failure();
        assert_eq!(t.state(), BackendState::Degraded, "one failure degrades");
        t.record_failure();
        assert_eq!(t.state(), BackendState::Degraded);
        t.record_failure();
        assert_eq!(t.state(), BackendState::Down, "3 consecutive failures");
        assert_eq!(t.failures(), 3);
        t.record_failure();
        assert_eq!(t.state(), BackendState::Down, "down is sticky under failures");
        t.record_success();
        assert_eq!(t.state(), BackendState::Up, "one success fully restores");
        assert_eq!(t.failures(), 0);
        t.record_failure();
        assert_eq!(t.state(), BackendState::Degraded, "streak restarted from zero");
    }

    #[test]
    fn down_after_is_clamped_to_at_least_one() {
        let mut t = HealthTracker::new(0);
        t.record_failure();
        assert_eq!(t.state(), BackendState::Down, "threshold 0 behaves as 1");
    }

    #[test]
    fn health_reply_parses_and_rejects_garbage() {
        let r = parse_health_reply("OK up=42 busy=3 lanes=8\n").unwrap();
        assert_eq!(r, ProbeReply { up_s: 42, busy: 3, lanes: 8 });
        // unknown fields are ignored (forward compatibility)
        let r = parse_health_reply("OK up=1 busy=0 lanes=4 extra=9").unwrap();
        assert_eq!(r.lanes, 4);
        assert!(parse_health_reply("ERR busy: shutting down").is_err());
        assert!(parse_health_reply("OK up=1 busy=0").is_err(), "missing lanes=");
        assert!(parse_health_reply("OK up=x busy=0 lanes=4").is_err(), "non-numeric");
        assert!(parse_health_reply("OK up busy=0 lanes=4").is_err(), "missing =");
    }
}
