//! Minimal line-oriented generation server (batch = 1, the paper's
//! real-time embedded setting).
//!
//! Protocol (one request per line over TCP):
//!   `GEN <steps> <prompt text...>`  →  one line: generated text
//!   `PING`                          →  `PONG`
//!   `QUIT`                          →  closes the connection
//!
//! Requests are served sequentially from a single engine — deliberately:
//! the paper argues batch-1 latency is the constraint on embedded devices,
//! so the server optimizes time-to-first-token over aggregate throughput.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{Context, Result};

use crate::engine::forward::Engine;
use crate::engine::generate::{generate, Sampler};
use crate::tokenizer::Tokenizer;

/// Serve until `max_requests` have been handled (None = forever).
pub struct Server {
    pub listener: TcpListener,
    pub tokenizer: Tokenizer,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str, vocab_size: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(Server { listener, tokenizer: Tokenizer::new(vocab_size) })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Run the accept loop on the calling thread.
    pub fn serve(&self, engine: &mut dyn Engine, max_requests: Option<usize>) -> Result<usize> {
        let mut handled = 0usize;
        for stream in self.listener.incoming() {
            let stream = stream?;
            handled += self.handle_conn(stream, engine)?;
            if let Some(max) = max_requests {
                if handled >= max {
                    break;
                }
            }
        }
        Ok(handled)
    }

    fn handle_conn(&self, stream: TcpStream, engine: &mut dyn Engine) -> Result<usize> {
        let mut out = stream.try_clone()?;
        let reader = BufReader::new(stream);
        let mut handled = 0usize;
        for line in reader.lines() {
            let line = line?;
            let reply = match self.handle_line(&line, engine) {
                Ok(Some(r)) => r,
                Ok(None) => break, // QUIT
                Err(e) => format!("ERR {e}"),
            };
            handled += 1;
            out.write_all(reply.as_bytes())?;
            out.write_all(b"\n")?;
            out.flush()?;
        }
        Ok(handled)
    }

    fn handle_line(&self, line: &str, engine: &mut dyn Engine) -> Result<Option<String>> {
        let line = line.trim();
        if line == "PING" {
            return Ok(Some("PONG".into()));
        }
        if line == "QUIT" {
            return Ok(None);
        }
        if let Some(rest) = line.strip_prefix("GEN ") {
            let (steps_str, prompt) = rest
                .split_once(' ')
                .context("usage: GEN <steps> <prompt>")?;
            let steps: usize = steps_str.parse().context("steps must be an integer")?;
            anyhow::ensure!(steps > 0 && steps <= engine.cfg().seq_len, "bad step count");
            let prompt_ids = self.tokenizer.encode(prompt, true);
            let out = generate(engine, &prompt_ids, steps, Sampler::Greedy, false)?;
            let text = self.tokenizer.decode(&out.generated);
            return Ok(Some(format!(
                "OK {:.3} tok/s | {}",
                out.tok_per_s,
                text.replace('\n', " ")
            )));
        }
        anyhow::bail!("unknown command (GEN/PING/QUIT)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::forward::CpuEngine;
    use crate::model::{FloatModel, LlamaConfig, QuantModel};
    use crate::ps::ScalarGqmv;
    use std::io::{BufRead, BufReader, Write};

    fn tiny_engine() -> CpuEngine {
        let cfg = LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 512,
            seq_len: 64,
            gs: 32,
        };
        CpuEngine::new(
            QuantModel::from_float(&FloatModel::random(cfg, 1)),
            Box::new(ScalarGqmv),
        )
    }

    #[test]
    fn ping_gen_quit_roundtrip() {
        let server = Server::bind("127.0.0.1:0", 512).unwrap();
        let addr = server.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut engine = tiny_engine();
            server.serve(&mut engine, Some(3)).unwrap()
        });
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        conn.write_all(b"PING\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");

        line.clear();
        conn.write_all(b"GEN 4 hello\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "{line}");

        line.clear();
        conn.write_all(b"GEN abc bad\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR "), "{line}");

        conn.write_all(b"QUIT\n").unwrap();
        drop(conn);
        let handled = t.join().unwrap();
        assert!(handled >= 3);
    }

    #[test]
    fn unknown_command_is_error_not_crash() {
        let server = Server::bind("127.0.0.1:0", 512).unwrap();
        let addr = server.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut engine = tiny_engine();
            server.serve(&mut engine, Some(1)).unwrap()
        });
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"BOGUS\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR "));
        // close the write half explicitly: `reader` holds a clone of the
        // socket, so merely dropping `conn` would keep the fd open and the
        // server's read loop alive.
        conn.write_all(b"QUIT\n").unwrap();
        drop(conn);
        t.join().unwrap();
    }
}
