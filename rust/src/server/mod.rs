//! Line-oriented generation server.
//!
//! Two serving modes share one TCP protocol (specified in
//! `docs/PROTOCOL.md`):
//!
//! * **Legacy batch-1** ([`Server::serve`]) — requests served sequentially
//!   from a single engine (the paper's real-time embedded setting, where
//!   batch-1 latency is the constraint).  Works with any [`Engine`],
//!   including the weight-streaming `LlamafEngine`.
//! * **Concurrent batched** ([`Server::serve_shared`]) — a multi-threaded
//!   accept loop feeding a bounded connection queue drained by N protocol
//!   workers.  Workers do not run private forward passes: every `GEN` /
//!   `SGEN` is submitted to one shared
//!   [`BatchScheduler`](crate::engine::batch::BatchScheduler), whose
//!   decode thread folds all concurrent requests into continuously
//!   batched passes — requests join at the very next step after arrival,
//!   prompts may prefill in bounded chunks (`--prefill-chunk`), and each
//!   layer's weights are staged once per step for the whole batch.
//!   Per-client KV state comes from a capacity-bounded [`SessionPool`]
//!   with LRU eviction; with `--kv-pages N` sessions draw KV storage
//!   from a shared page pool with copy-on-write prompt-prefix reuse
//!   instead of owning contiguous slabs.  Greedy outputs are
//!   byte-identical to batch-1 serving.  Weights are streamed (staged
//!   once per step via the persistent prefetch worker) by default, or
//!   served zero-copy with `--resident` when the model truly fits
//!   device-side.
//!
//! Protocol (one request per line over TCP):
//!   `GEN <steps> <prompt text...>`  →  one line: `OK <tok/s> | <text>`
//!   `SGEN <steps> <prompt text...>` →  `TOK <step> <id> <piece>` per
//!                                      token, then `DONE <n> <tok/s>`
//!                                      (shared mode)
//!   `STATS`                         →  one-line metrics snapshot
//!                                      (sessions, queue, latency, batch
//!                                      occupancy, bytes staged)
//!   `TRACE`                         →  `OK trace <k=v ...>` — per-request
//!                                      timing breakdown of the LAST
//!                                      completed generation on this
//!                                      connection (shared mode)
//!   `METRICS`                       →  `METRICS <n>` then `n` lines of
//!                                      `llamaf_<name> <value>` — a
//!                                      scrapeable flat text export of
//!                                      every gauge/counter (shared mode)
//!   `PING`                          →  `PONG`
//!   `HEALTH`                        →  one line: `OK up=<s> busy=<n>
//!                                      lanes=<n>` — uptime seconds, busy
//!                                      sessions, lane capacity; the
//!                                      minimal liveness probe gateway
//!                                      health checks poll (shared mode)
//!   `SHUTDOWN`                      →  `OK shutting down`; stops
//!                                      accepting (a late connection gets
//!                                      an immediate `ERR busy`, never a
//!                                      hang), drains queued connections,
//!                                      then exits (shared mode)
//!   `QUIT`                          →  closes the connection
//!
//! Scale-out serving fronts N replicas of this server with the
//! [`gateway`] module: health-checked least-loaded routing ([`router`],
//! [`health`]) speaking this same protocol on both sides.
//!
//! Overload behaviour is explicit: when the connection queue is full the
//! accept loop answers `ERR busy` and closes instead of queueing unbounded
//! work; when every session is checked out, `GEN`/`SGEN` answer `ERR busy`.
//!
//! Failure behaviour is equally explicit (see `docs/ARCHITECTURE.md`,
//! "Failure domains"): an I/O fault that survives the staging retries and
//! the step retries sheds exactly one lane with `ERR fault:`; a request
//! past its `--request-timeout` deadline is shed with `ERR deadline:`.
//! Both leave every other lane decoding bit-identically.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::batch::{BatchOpts, BatchScheduler, WeightMode};
use crate::engine::forward::Engine;
use crate::engine::generate::{generate, Sampler};
use crate::engine::session::{Session, SessionPool};
use crate::metrics::{RequestTrace, ServerMetrics};
use crate::model::{LlamaConfig, PagePool, QuantModel, DEFAULT_PAGE_POSITIONS};
use crate::ps::gqmv::GqmvExec;
use crate::sched::{SchedMode, StageGranularity};
use crate::tokenizer::Tokenizer;

pub mod gateway;
pub mod health;
pub mod router;

/// Factory building GQMV backends (the batch scheduler's decode thread
/// gets one; the backend must be `Send` to move onto it).
pub type ExecFactory = dyn Fn() -> Box<dyn GqmvExec + Send> + Sync;

/// Knobs of the concurrent serving mode.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Protocol worker threads (connection parsing + streaming replies).
    pub workers: usize,
    /// Pending-connection queue bound; overflow is answered `ERR busy`.
    pub queue_depth: usize,
    /// Session-pool capacity (bounds total KV-cache memory).
    pub max_sessions: usize,
    /// Maximum lanes per batched decode step.
    pub max_batch: usize,
    /// Stage layer weights synchronously instead of via the async
    /// prefetch (Fig. 2 top vs bottom; for A/B measurement).  Only
    /// meaningful when streaming; rejected together with `resident`.
    pub sync_staging: bool,
    /// Staging-ring depth of the decode thread's weight streamer (CLI
    /// `--prefetch-depth`): 1 resident unit + `prefetch_depth - 1`
    /// transfers in flight.  Default 2 (double buffering); ignored with
    /// `resident`, degenerate (inline staging) at 1.
    pub prefetch_depth: usize,
    /// Unit of staging the decode thread's streamer pipelines (CLI
    /// `--stream-granularity`): whole layers (default) or per-matrix
    /// chunks, which overlap transfers *within* a layer.  Ignored with
    /// `resident`.
    pub granularity: StageGranularity,
    /// Serve zero-copy resident weights ([`WeightMode::Resident`])
    /// instead of streaming them through the staging scheduler — for
    /// deployments where the model truly fits device-side.
    pub resident: bool,
    /// Shared KV page-pool capacity in pages of
    /// [`DEFAULT_PAGE_POSITIONS`] positions (CLI `--kv-pages`); 0 (the
    /// default) keeps the contiguous per-session KV slabs.  Paged
    /// sessions get copy-on-write prompt-prefix reuse across requests.
    pub kv_pages: usize,
    /// Maximum prompt tokens one request may prefill per batched step
    /// (CLI `--prefill-chunk`); 1 (the default) is the classic one token
    /// per step.  Bit-identical at any value.
    pub prefill_chunk: usize,
    /// Per-request deadline in milliseconds (CLI `--request-timeout`);
    /// the clock starts at submission, so queue wait counts against it.
    /// A lane past its deadline is shed with `ERR deadline:` while the
    /// rest of the batch keeps decoding.  None (the default) = no limit.
    pub request_timeout_ms: Option<u64>,
    /// Deterministic I/O fault-injection plan applied to the decode
    /// thread's staged reads (CLI `--inject-faults`); None = no injection.
    /// Test-only in spirit, but safe in production: an empty plan is a
    /// passthrough.
    pub faults: Option<crate::sched::FaultPlan>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            workers: 4,
            queue_depth: 64,
            max_sessions: 16,
            max_batch: 8,
            sync_staging: false,
            prefetch_depth: crate::sched::DEFAULT_PREFETCH_DEPTH,
            granularity: StageGranularity::default(),
            resident: false,
            kv_pages: 0,
            prefill_chunk: 1,
            request_timeout_ms: None,
            faults: None,
        }
    }
}

/// What a `serve_shared` run did (tests and the CLI summary).
#[derive(Clone, Copy, Debug)]
pub struct ServeReport {
    /// Connections taken by the accept loop (including rejected ones).
    pub accepted: usize,
    /// Completed generation requests.
    pub requests: u64,
    /// Connections/requests answered `ERR busy`.
    pub rejected: u64,
    /// Tokens generated across all requests.
    pub tokens: u64,
    /// Sessions idle in the pool when the server drained.
    pub idle_at_exit: usize,
    /// Sessions still checked out at drain — 0 unless a session was lost
    /// with the decode thread (soak tests pin this).
    pub busy_at_exit: usize,
    /// Live KV pages left after the drained pool's idle sessions and the
    /// prefix cache were released — 0 if the page ledger balances (soak
    /// tests pin this; always 0 without `--kv-pages`).
    pub kv_pages_at_exit: usize,
}

/// State shared by the accept loop and every worker.
struct Shared {
    queue: Mutex<VecDeque<TcpStream>>,
    cv: Condvar,
    shutdown: AtomicBool,
    pool: SessionPool,
    metrics: ServerMetrics,
    sched: Arc<BatchScheduler>,
    cfg: LlamaConfig,
    /// `resident` or `streamed` — surfaced in `STATS`.
    weights: &'static str,
    /// Per-request deadline every submission carries (None = no limit).
    timeout: Option<Duration>,
    next_conn: AtomicU64,
    workers_live: AtomicUsize,
    addr: std::net::SocketAddr,
    /// When serving started — `HEALTH` reports whole-second uptime.
    started: Instant,
    /// Lane capacity per batched step — `HEALTH` reports it as `lanes=`.
    max_batch: usize,
}

impl Shared {
    /// Signal shutdown and unblock both the workers and the accept loop
    /// (the latter by poking a throwaway connection at ourselves).
    fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.cv.notify_all();
        let _ = TcpStream::connect(self.addr);
    }
}

/// A bound TCP generation server (see the module docs for the protocol).
pub struct Server {
    /// The bound listener the accept loop runs on.
    pub listener: TcpListener,
    /// Byte-level tokenizer shared by every connection.
    pub tokenizer: Tokenizer,
}

impl Server {
    /// Bind to `addr` (e.g. "127.0.0.1:0" for an ephemeral port).
    pub fn bind(addr: &str, vocab_size: usize) -> Result<Self> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        Ok(Server { listener, tokenizer: Tokenizer::new(vocab_size) })
    }

    /// Address the listener actually bound (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    // ------------------------------------------------------------------
    // Legacy batch-1 mode
    // ------------------------------------------------------------------

    /// Run the sequential accept loop on the calling thread until
    /// `max_requests` have been handled (None = forever).
    pub fn serve(&self, engine: &mut dyn Engine, max_requests: Option<usize>) -> Result<usize> {
        let mut handled = 0usize;
        for stream in self.listener.incoming() {
            let stream = stream?;
            handled += self.handle_conn(stream, engine)?;
            if let Some(max) = max_requests {
                if handled >= max {
                    break;
                }
            }
        }
        Ok(handled)
    }

    fn handle_conn(&self, stream: TcpStream, engine: &mut dyn Engine) -> Result<usize> {
        let mut out = stream.try_clone()?;
        let reader = BufReader::new(stream);
        let mut handled = 0usize;
        for line in reader.lines() {
            let line = line?;
            let reply = match self.handle_line(&line, engine) {
                Ok(Some(r)) => r,
                Ok(None) => break, // QUIT
                Err(e) => format!("ERR {e}"),
            };
            handled += 1;
            out.write_all(reply.as_bytes())?;
            out.write_all(b"\n")?;
            out.flush()?;
        }
        Ok(handled)
    }

    fn handle_line(&self, line: &str, engine: &mut dyn Engine) -> Result<Option<String>> {
        let line = line.trim();
        if line == "PING" {
            return Ok(Some("PONG".into()));
        }
        if line == "QUIT" {
            return Ok(None);
        }
        if let Some(rest) = line.strip_prefix("GEN ") {
            let (steps, prompt) = parse_gen(rest, engine.cfg().seq_len)?;
            let prompt_ids = self.tokenizer.encode(prompt, true);
            let out = generate(engine, &prompt_ids, steps, Sampler::Greedy, false)?;
            let text = self.tokenizer.decode(&out.generated);
            return Ok(Some(format!("OK {:.3} tok/s | {}", out.tok_per_s, text.replace('\n', " "))));
        }
        anyhow::bail!("unknown command (GEN/PING/QUIT)")
    }

    // ------------------------------------------------------------------
    // Concurrent shared-weight mode
    // ------------------------------------------------------------------

    /// Serve with `opts.workers` protocol threads over one shared weight
    /// copy, decoding through a step-synchronous
    /// [`BatchScheduler`](crate::engine::batch::BatchScheduler).
    ///
    /// `make_exec` builds the decode thread's GQMV backend.  `max_conns`
    /// bounds how many connections the accept loop takes before draining
    /// and returning (None = until `SHUTDOWN`); rejected (queue-full)
    /// connections count as accepted.
    pub fn serve_shared(
        &self,
        model: Arc<QuantModel>,
        make_exec: &ExecFactory,
        opts: &ServeOpts,
        max_conns: Option<usize>,
    ) -> Result<ServeReport> {
        anyhow::ensure!(opts.workers >= 1, "need at least one worker");
        anyhow::ensure!(opts.queue_depth >= 1, "need a queue depth of at least 1");
        anyhow::ensure!(opts.max_batch >= 1, "need a batch capacity of at least 1");
        anyhow::ensure!(opts.prefetch_depth >= 1, "need a prefetch depth of at least 1");
        anyhow::ensure!(opts.prefill_chunk >= 1, "need a prefill chunk of at least 1");
        anyhow::ensure!(
            !(opts.resident && opts.sync_staging),
            "--resident serves from memory; --sync only applies to streamed staging"
        );
        // resolve the address BEFORE spawning the decode thread: any `?`
        // between scheduler creation and `sched.shutdown()` would leak it
        let addr = self.local_addr()?;
        let sched = BatchScheduler::with_faults(
            Arc::clone(&model),
            make_exec(),
            BatchOpts {
                max_batch: opts.max_batch,
                // a lane requires a checked-out session, so the pool
                // already caps concurrent lanes; mirror that bound here
                max_pending: opts.max_sessions.max(opts.max_batch),
                sched: if opts.sync_staging { SchedMode::Sync } else { SchedMode::Async },
                prefetch_depth: opts.prefetch_depth,
                granularity: opts.granularity,
                weights: if opts.resident { WeightMode::Resident } else { WeightMode::Streamed },
                prefill_chunk: opts.prefill_chunk,
                ..Default::default()
            },
            opts.faults.clone(),
        );
        let page_pool = (opts.kv_pages > 0)
            .then(|| Arc::new(PagePool::new(&model.cfg, opts.kv_pages, DEFAULT_PAGE_POSITIONS)));
        let shared = Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            pool: match &page_pool {
                Some(p) => SessionPool::with_pages(model.cfg, opts.max_sessions, Arc::clone(p)),
                None => SessionPool::new(model.cfg, opts.max_sessions),
            },
            metrics: ServerMetrics::default(),
            sched: Arc::clone(&sched),
            cfg: model.cfg,
            weights: if opts.resident { "resident" } else { "streamed" },
            timeout: opts.request_timeout_ms.map(Duration::from_millis),
            next_conn: AtomicU64::new(0),
            // pre-counted (decrement-only) so a SHUTDOWN racing worker
            // startup can't observe 0 and skip the drain loop below
            workers_live: AtomicUsize::new(opts.workers),
            addr,
            started: Instant::now(),
            max_batch: opts.max_batch,
        };
        let mut accepted = 0usize;

        // Shut the decode thread down on EVERY exit path: a panic inside
        // the scope (e.g. a worker assertion) unwinds past the normal
        // call below, and an un-shutdown scheduler pins its thread, the
        // scratch, the streamer, and a model Arc for the process
        // lifetime.  shutdown() is idempotent, so the guard and the
        // explicit call coexist.
        struct ShutdownGuard<'a>(&'a BatchScheduler);
        impl Drop for ShutdownGuard<'_> {
            fn drop(&mut self) {
                self.0.shutdown();
            }
        }
        let shutdown_guard = ShutdownGuard(&sched);

        let scope_result = std::thread::scope(|scope| -> Result<()> {
            for wi in 0..opts.workers {
                let shared = &shared;
                std::thread::Builder::new()
                    .name(format!("llamaf-serve-{wi}"))
                    .spawn_scoped(scope, move || {
                        while let Some(conn) = next_conn(shared) {
                            if let Err(e) = self.handle_shared_conn(conn, shared) {
                                eprintln!("llamaf-serve-{wi}: connection error: {e:#}");
                            }
                        }
                        shared.workers_live.fetch_sub(1, Ordering::SeqCst);
                    })
                    .expect("spawn serve worker");
            }

            for stream in self.listener.incoming() {
                if shared.shutdown.load(Ordering::SeqCst) {
                    // The stream that woke us is usually the shutdown
                    // self-poke (already closed — the write fails
                    // harmlessly), but it may be a real client racing the
                    // shutdown: refuse it honestly either way.
                    if let Ok(mut s) = stream {
                        let _ = s.write_all(b"ERR busy: server shutting down\n");
                    }
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                accepted += 1;
                let mut q = shared.queue.lock().unwrap();
                if q.len() >= opts.queue_depth {
                    drop(q);
                    shared.metrics.record_rejected();
                    let mut s = stream;
                    let _ = s.write_all(b"ERR busy: connection queue full\n");
                    let _ = s.flush();
                } else {
                    q.push_back(stream);
                    shared.metrics.set_queue_depth(q.len());
                    shared.cv.notify_one();
                }
                if let Some(max) = max_conns {
                    if accepted >= max {
                        break;
                    }
                }
            }
            // Drain: workers finish everything already queued, then exit.
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
            // Accepting stopped BEFORE the drain — but the listener stays
            // bound, so a client connecting mid-drain would otherwise sit
            // in the OS backlog until its own timeout.  Keep servicing
            // the listener while workers finish, answering each late
            // connection with an immediate honest refusal.  (Counters are
            // left untouched: the shutdown self-poke can land here, and
            // it must not perturb accepted/rejected accounting.)
            self.listener.set_nonblocking(true)?;
            while shared.workers_live.load(Ordering::SeqCst) > 0 {
                match self.listener.accept() {
                    Ok((mut s, _)) => {
                        let _ = s.write_all(b"ERR busy: server shutting down\n");
                        let _ = s.flush();
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            let _ = self.listener.set_nonblocking(false);
            Ok(())
        });
        // All workers have joined; no lanes can be in flight any more.
        drop(shutdown_guard);
        scope_result?;

        let (idle_at_exit, busy_at_exit) = shared.pool.counts();
        let requests = shared.metrics.requests.load(Ordering::Relaxed);
        let rejected = shared.metrics.rejected.load(Ordering::Relaxed);
        let tokens = shared.metrics.tokens.load(Ordering::Relaxed);
        // Page-ledger drain check: dropping the session pool releases
        // every idle session's pages, clearing the prefix cache releases
        // the rest — a balanced ledger then reads exactly 0.
        drop(shared);
        let kv_pages_at_exit = page_pool
            .map(|p| {
                p.clear_cache();
                p.pages_used()
            })
            .unwrap_or(0);

        Ok(ServeReport {
            accepted,
            requests,
            rejected,
            tokens,
            idle_at_exit,
            busy_at_exit,
            kv_pages_at_exit,
        })
    }

    fn handle_shared_conn(&self, stream: TcpStream, shared: &Shared) -> Result<()> {
        let conn_id = shared.next_conn.fetch_add(1, Ordering::Relaxed);
        let mut out = stream.try_clone()?;
        let reader = BufReader::new(stream);
        let mut session: Option<Session> = None;
        let mut last_trace: Option<RequestTrace> = None;

        let mut result = Ok(());
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(e) => {
                    result = Err(e.into());
                    break;
                }
            };
            let line = line.trim().to_string();
            if line == "QUIT" {
                break;
            }
            let reply = self
                .shared_command(&line, shared, conn_id, &mut session, &mut last_trace, &mut out);
            match reply {
                Ok(Some(r)) => {
                    if out.write_all(r.as_bytes()).and_then(|_| out.write_all(b"\n")).is_err() {
                        break; // client went away mid-reply
                    }
                    let _ = out.flush();
                }
                Ok(None) => {} // streaming command wrote its own lines
                Err(e) => {
                    let _ = out.write_all(format!("ERR {e}\n").as_bytes());
                    let _ = out.flush();
                }
            }
            if line == "SHUTDOWN" {
                break;
            }
        }
        if let Some(sess) = session.take() {
            shared.pool.release(conn_id, sess);
        }
        result
    }

    /// Execute one shared-mode command.  `Ok(Some(reply))` for one-line
    /// replies, `Ok(None)` when the command streamed its own output.
    /// `last_trace` is per-connection state: the [`RequestTrace`] of the
    /// most recent completed generation, served back by `TRACE`.
    fn shared_command(
        &self,
        line: &str,
        shared: &Shared,
        conn_id: u64,
        session: &mut Option<Session>,
        last_trace: &mut Option<RequestTrace>,
        out: &mut TcpStream,
    ) -> Result<Option<String>> {
        if line == "PING" {
            return Ok(Some("PONG".into()));
        }
        if line == "HEALTH" {
            // One line, three fields, no histogram math: cheap enough for
            // a gateway to poll every probe interval without parsing the
            // full STATS reply.
            let (_idle, in_use) = shared.pool.counts();
            return Ok(Some(format!(
                "OK up={} busy={in_use} lanes={}",
                shared.started.elapsed().as_secs(),
                shared.max_batch,
            )));
        }
        if line == "SHUTDOWN" {
            shared.begin_shutdown();
            return Ok(Some("OK shutting down".into()));
        }
        if line == "STATS" {
            let (idle, in_use) = shared.pool.counts();
            return Ok(Some(format!(
                "OK sessions_idle={idle} sessions_busy={in_use} sessions_cap={} workers={} \
                 weights={} {} {} {}",
                shared.pool.capacity(),
                shared.workers_live.load(Ordering::SeqCst),
                shared.weights,
                shared.metrics.summary(),
                shared.sched.metrics().summary(),
                page_pool_summary(shared),
            )));
        }
        if line == "TRACE" {
            let t = last_trace
                .as_ref()
                .context("no completed generation on this connection (run GEN/SGEN first)")?;
            return Ok(Some(format!("OK trace {}", t.summary())));
        }
        if line == "METRICS" {
            let lines = metrics_lines(shared);
            out.write_all(format!("METRICS {}\n", lines.len()).as_bytes())?;
            for (name, value) in lines {
                out.write_all(format!("llamaf_{name} {value}\n").as_bytes())?;
            }
            out.flush()?;
            return Ok(None);
        }
        let (streaming, rest) = if let Some(r) = line.strip_prefix("SGEN ") {
            (true, r)
        } else if let Some(r) = line.strip_prefix("GEN ") {
            (false, r)
        } else {
            anyhow::bail!(
                "unknown command (GEN/SGEN/STATS/TRACE/METRICS/PING/HEALTH/SHUTDOWN/QUIT)"
            )
        };

        let (steps, prompt) = parse_gen(rest, shared.cfg.seq_len)?;
        if session.is_none() {
            match shared.pool.acquire(conn_id) {
                Ok(s) => *session = Some(s),
                Err(_) => {
                    shared.metrics.record_rejected();
                    anyhow::bail!("busy: all sessions in use")
                }
            }
        }
        let sess = session.take().expect("session acquired above");
        let prompt_ids = self.tokenizer.encode(prompt, true);

        // Submit to the shared batch scheduler: the decode thread folds
        // this request into its step-synchronous batch; tokens stream
        // back through the closure on THIS thread, so a slow client
        // never stalls the batch.
        let t = Instant::now();
        let (sess_back, gen) = if streaming {
            shared.sched.generate_with_deadline(sess, &prompt_ids, steps, shared.timeout, |i, id| {
                let piece = self.tokenizer.decode_one(id).replace('\n', " ");
                out.write_all(format!("TOK {i} {id} {piece}\n").as_bytes())?;
                out.flush()?;
                Ok(())
            })
        } else {
            shared.sched.generate_with_deadline(sess, &prompt_ids, steps, shared.timeout, |_, _| {
                Ok(())
            })
        };
        *session = sess_back; // released to the pool when the conn closes
        if session.is_none() {
            // the session died with the decode thread; give its capacity
            // slot back so the pool's accounting stays truthful
            shared.pool.forget(conn_id);
        }
        let gen = match gen {
            Ok(g) => g,
            Err(e) => {
                // scheduler saturation is load shedding: count it like
                // the other busy rejections so STATS stays truthful
                if e.to_string().starts_with(crate::engine::batch::BUSY_ERR_PREFIX) {
                    shared.metrics.record_rejected();
                }
                return Err(e);
            }
        };
        shared.metrics.record_request(t.elapsed().as_secs_f64(), gen.generated.len() as u64);
        if let Some(trace) = &gen.trace {
            shared.metrics.record_trace(trace);
            *last_trace = Some(trace.clone());
        }

        if streaming {
            out.write_all(
                format!("DONE {} {:.3} tok/s\n", gen.generated.len(), gen.tok_per_s).as_bytes(),
            )?;
            out.flush()?;
            Ok(None)
        } else {
            let text = self.tokenizer.decode(&gen.generated);
            Ok(Some(format!("OK {:.3} tok/s | {}", gen.tok_per_s, text.replace('\n', " "))))
        }
    }
}

/// Page-pool segment of the `STATS` reply.  All five fields are present
/// in every reply (zeros without `--kv-pages`) so scrapers never branch
/// on server configuration.
fn page_pool_summary(shared: &Shared) -> String {
    match shared.pool.page_pool() {
        Some(p) => format!(
            "page_hits={} page_misses={} page_evictions={} kv_pages_used={} kv_pages_cap={}",
            p.hits(),
            p.misses(),
            p.evictions(),
            p.pages_used(),
            p.capacity,
        ),
        None => {
            "page_hits=0 page_misses=0 page_evictions=0 kv_pages_used=0 kv_pages_cap=0".into()
        }
    }
}

/// Pop the next queued connection, or None when shut down and drained.
fn next_conn(shared: &Shared) -> Option<TcpStream> {
    let mut q = shared.queue.lock().unwrap();
    loop {
        if let Some(conn) = q.pop_front() {
            shared.metrics.set_queue_depth(q.len());
            return Some(conn);
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return None;
        }
        q = shared.cv.wait(q).unwrap();
    }
}

/// Every gauge/counter of the `METRICS` export as `(name, value)` pairs
/// (without the `llamaf_` prefix), in the pinned order documented in
/// `docs/OBSERVABILITY.md`.  All values are plain decimal numbers.
fn metrics_lines(shared: &Shared) -> Vec<(&'static str, String)> {
    let (idle, busy) = shared.pool.counts();
    let m = &shared.metrics;
    let b = shared.sched.metrics();
    let (lat_p50, lat_p99, lat_mean) = m.latency_ms();
    let (qw_p50, qw_p99) = m.queue_wait_ms_p50_p99();
    let prof = b.profile();
    let prof_total = prof.total();
    let matrix_pct = if prof_total > 0.0 { 100.0 * prof.matrix_s / prof_total } else { 0.0 };
    let mw = b.unit_wait_ms();
    let pp = shared.pool.page_pool();
    vec![
        ("sessions_idle", idle.to_string()),
        ("sessions_busy", busy.to_string()),
        ("sessions_cap", shared.pool.capacity().to_string()),
        ("workers", shared.workers_live.load(Ordering::SeqCst).to_string()),
        ("requests_total", m.requests.load(Ordering::Relaxed).to_string()),
        ("rejected_total", m.rejected.load(Ordering::Relaxed).to_string()),
        ("tokens_total", m.tokens.load(Ordering::Relaxed).to_string()),
        ("queue_depth", m.queue_depth().to_string()),
        ("queue_peak", m.queue_peak().to_string()),
        ("request_latency_p50_ms", format!("{lat_p50:.3}")),
        ("request_latency_p99_ms", format!("{lat_p99:.3}")),
        ("request_latency_mean_ms", format!("{lat_mean:.3}")),
        ("request_tok_s_p50", format!("{:.3}", m.tok_s_p50())),
        ("traced_requests_total", m.traced().to_string()),
        ("queue_wait_ms_p50", format!("{qw_p50:.3}")),
        ("queue_wait_ms_p99", format!("{qw_p99:.3}")),
        ("prefill_seconds_total", format!("{:.6}", m.prefill_s())),
        ("decode_seconds_total", format!("{:.6}", m.decode_s())),
        ("prefill_tokens_total", m.prefill_tokens().to_string()),
        ("decode_tokens_total", m.decode_tokens().to_string()),
        ("batch_steps_total", b.steps().to_string()),
        ("batch_lane_tokens_total", b.lane_tokens().to_string()),
        ("batch_occupancy_mean", format!("{:.3}", b.occupancy_mean())),
        ("batch_occupancy_max", format!("{:.3}", b.occupancy_max())),
        ("staged_bytes_total", b.bytes_staged().to_string()),
        ("staged_bytes_per_token", format!("{:.1}", b.bytes_per_token())),
        ("prefetch_wait_ms_total", format!("{:.3}", 1e3 * b.prefetch_wait_s())),
        ("prefetch_depth", b.ring_depth().to_string()),
        ("ring_occupancy", format!("{:.3}", b.ring_occupancy())),
        ("stage_mb_s", format!("{:.3}", b.stage_mb_s())),
        ("mat_wait_ms_norms", format!("{:.3}", mw[0])),
        ("mat_wait_ms_qkv", format!("{:.3}", mw[1])),
        ("mat_wait_ms_wo", format!("{:.3}", mw[2])),
        ("mat_wait_ms_w13", format!("{:.3}", mw[3])),
        ("mat_wait_ms_w2", format!("{:.3}", mw[4])),
        ("matrix_time_pct", format!("{matrix_pct:.1}")),
        ("weights_resident", if shared.weights == "resident" { "1" } else { "0" }.to_string()),
        ("granularity_matrix", if b.granularity() == "matrix" { "1" } else { "0" }.to_string()),
        ("admission_ms_mean", format!("{:.3}", b.admission_ms_mean())),
        ("prefill_chunk", b.prefill_chunk().to_string()),
        ("chunk_feeds_total", b.chunk_feeds().to_string()),
        ("stage_retries_total", b.stage_retries().to_string()),
        ("stage_faults_total", b.stage_faults().to_string()),
        ("stage_timeouts_total", b.stage_timeouts().to_string()),
        ("step_retries_total", b.step_retries().to_string()),
        ("lane_faults_total", b.lane_faults().to_string()),
        ("deadline_expired_total", b.deadline_expired().to_string()),
        ("page_hits_total", pp.map(|p| p.hits()).unwrap_or(0).to_string()),
        ("page_misses_total", pp.map(|p| p.misses()).unwrap_or(0).to_string()),
        ("page_evictions_total", pp.map(|p| p.evictions()).unwrap_or(0).to_string()),
        ("kv_pages_used", pp.map(|p| p.pages_used()).unwrap_or(0).to_string()),
        ("kv_pages_cap", pp.map(|p| p.capacity).unwrap_or(0).to_string()),
    ]
}

/// Parse `"<steps> <prompt...>"`, validating the step count.
fn parse_gen(rest: &str, seq_len: usize) -> Result<(usize, &str)> {
    let (steps_str, prompt) = rest.split_once(' ').context("usage: GEN <steps> <prompt>")?;
    let steps: usize = steps_str.parse().context("steps must be an integer")?;
    anyhow::ensure!(steps > 0 && steps <= seq_len, "bad step count");
    Ok((steps, prompt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::forward::CpuEngine;
    use crate::model::{FloatModel, LlamaConfig, QuantModel};
    use crate::ps::ScalarGqmv;
    use std::io::{BufRead, BufReader, Write};

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 512,
            seq_len: 64,
            gs: 32,
        }
    }

    fn tiny_engine() -> CpuEngine {
        CpuEngine::new(
            QuantModel::from_float(&FloatModel::random(tiny_cfg(), 1)),
            Box::new(ScalarGqmv),
        )
    }

    fn tiny_model() -> Arc<QuantModel> {
        Arc::new(QuantModel::from_float(&FloatModel::random(tiny_cfg(), 1)))
    }

    fn scalar_exec() -> Box<dyn GqmvExec + Send> {
        Box::new(ScalarGqmv)
    }

    #[test]
    fn ping_gen_quit_roundtrip() {
        let server = Server::bind("127.0.0.1:0", 512).unwrap();
        let addr = server.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut engine = tiny_engine();
            server.serve(&mut engine, Some(3)).unwrap()
        });
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        conn.write_all(b"PING\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");

        line.clear();
        conn.write_all(b"GEN 4 hello\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "{line}");

        line.clear();
        conn.write_all(b"GEN abc bad\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR "), "{line}");

        conn.write_all(b"QUIT\n").unwrap();
        drop(conn);
        let handled = t.join().unwrap();
        assert!(handled >= 3);
    }

    #[test]
    fn unknown_command_is_error_not_crash() {
        let server = Server::bind("127.0.0.1:0", 512).unwrap();
        let addr = server.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut engine = tiny_engine();
            server.serve(&mut engine, Some(1)).unwrap()
        });
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        conn.write_all(b"BOGUS\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR "));
        // close the write half explicitly: `reader` holds a clone of the
        // socket, so merely dropping `conn` would keep the fd open and the
        // server's read loop alive.
        conn.write_all(b"QUIT\n").unwrap();
        drop(conn);
        t.join().unwrap();
    }

    #[test]
    fn health_reports_uptime_busy_and_lanes() {
        let server = Server::bind("127.0.0.1:0", 512).unwrap();
        let addr = server.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let opts = ServeOpts { workers: 1, max_batch: 4, ..ServeOpts::default() };
            server.serve_shared(tiny_model(), &scalar_exec, &opts, Some(1)).unwrap()
        });
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        conn.write_all(b"HEALTH\n").unwrap();
        reader.read_line(&mut line).unwrap();
        let l = line.trim();
        assert!(l.starts_with("OK up="), "{l}");
        assert!(l.contains(" busy=0"), "no session checked out by HEALTH alone: {l}");
        assert!(l.ends_with(" lanes=4"), "lanes = configured max_batch: {l}");
        conn.write_all(b"QUIT\n").unwrap();
        drop(conn);
        t.join().unwrap();
    }

    #[test]
    fn connect_during_drain_is_refused_not_hung() {
        // Regression: SHUTDOWN stops accepting BEFORE the worker drain.
        // A client connecting while workers finish queued connections
        // must get an immediate honest refusal, not hang in the OS
        // backlog until its own timeout.
        let server = Server::bind("127.0.0.1:0", 512).unwrap();
        let addr = server.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let opts = ServeOpts { workers: 1, ..ServeOpts::default() };
            server.serve_shared(tiny_model(), &scalar_exec, &opts, None).unwrap()
        });
        // A occupies the single worker
        let mut a = std::net::TcpStream::connect(addr).unwrap();
        let mut ra = BufReader::new(a.try_clone().unwrap());
        let mut line = String::new();
        a.write_all(b"PING\n").unwrap();
        ra.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "PONG");
        // B parks in the connection queue behind A and holds the drain
        // open (the worker will block reading it until it QUITs)
        let mut b = std::net::TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        line.clear();
        a.write_all(b"SHUTDOWN\n").unwrap();
        ra.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "OK shutting down");
        a.write_all(b"QUIT\n").unwrap();
        drop(a);
        // give the accept loop a moment to switch into drain mode
        std::thread::sleep(Duration::from_millis(50));
        // C connects during the drain
        let c = std::net::TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut rc = BufReader::new(c);
        line.clear();
        rc.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ERR busy: server shutting down");
        // B drains normally, then the server exits
        b.write_all(b"QUIT\n").unwrap();
        drop(b);
        t.join().unwrap();
    }
}
