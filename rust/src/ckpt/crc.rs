//! Hand-rolled CRC-32 (IEEE 802.3: reflected, polynomial `0xEDB88320`)
//! — the checksum of the checkpoint integrity footer.
//!
//! The table is built at compile time; no dependencies.  This is the
//! same CRC-32 as zlib/PNG/gzip, so footers can be cross-checked with
//! standard tools (`python -c "import zlib; print(zlib.crc32(...))"`).

/// Byte-indexed CRC table for the reflected polynomial `0xEDB88320`.
const TABLE: [u32; 256] = make_table();

const fn make_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

/// Streaming CRC-32 state: feed bytes with [`Crc32::update`], read the
/// digest with [`Crc32::finish`].  Useful when the checksummed region is
/// larger than what should be held in memory (checkpoint segments are
/// streamed through a fixed buffer).
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    /// Fresh CRC state (all-ones preset, per the IEEE definition).
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running CRC.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = TABLE[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// Final digest (with the standard output inversion).
    pub fn finish(self) -> u32 {
        !self.state
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the canonical CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(37) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(&data));
    }

    #[test]
    fn single_bit_flip_changes_digest() {
        let mut data: Vec<u8> = (0..100u8).collect();
        let clean = crc32(&data);
        data[42] ^= 0x10;
        assert_ne!(crc32(&data), clean);
    }
}
