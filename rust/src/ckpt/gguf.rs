//! GGUF import: read llama.cpp-ecosystem checkpoints into native LFQ*
//! files.
//!
//! GGUF (v2/v3) is a little-endian container: magic `GGUF`, version,
//! tensor count, a string-keyed metadata table, tensor descriptors
//! (name, dims, ggml type, data offset), then an aligned data section.
//! We parse the metadata generically (every value type is length-
//! delimited, so unknown keys skip cleanly), dequantize the ggml block
//! formats we understand (F32, F16, Q8_0, Q4_0, Q5_0) to f32, assemble
//! a [`FloatModel`] from the standard llama tensor names, and re-
//! quantize through the native write path.
//!
//! Re-quantizing instead of transcoding blocks is deliberate: ggml
//! blocks are a fixed 32 elements while our group size must equal the
//! model's activation group size (the GQMV cast chain pairs weight and
//! activation scales group-for-group), so block boundaries do not line
//! up.  The cost is one extra rounding step; the payoff is that an
//! imported checkpoint is byte-compatible with every native consumer —
//! streaming layouts, staging ring, kernels — with no special cases.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::{FloatLayer, FloatModel, LlamaConfig};
use crate::quant::FormatId;

// ggml tensor type ids (ggml.h)
pub const GGML_F32: u32 = 0;
pub const GGML_F16: u32 = 1;
pub const GGML_Q4_0: u32 = 2;
pub const GGML_Q5_0: u32 = 6;
pub const GGML_Q8_0: u32 = 8;

/// Elements per ggml quantized block (fixed by the format family).
pub const GGML_BLOCK: usize = 32;

const DEFAULT_ALIGNMENT: u64 = 32;

// ---------------------------------------------------------------------------
// half-precision conversion (the crate has no half dependency)
// ---------------------------------------------------------------------------

/// IEEE 754 binary16 -> f32 (handles subnormals, inf, NaN).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = (h as u32 >> 15) & 1;
    let exp = (h as u32 >> 10) & 0x1F;
    let frac = h as u32 & 0x3FF;
    let bits = if exp == 0 {
        if frac == 0 {
            sign << 31
        } else {
            // subnormal: renormalize into f32's larger exponent range
            let mut e = 113u32; // 127 - 15 + 1
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            (sign << 31) | (e << 23) | ((f & 0x3FF) << 13)
        }
    } else if exp == 0x1F {
        (sign << 31) | (0xFF << 23) | (frac << 13)
    } else {
        (sign << 31) | ((exp + 112) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

/// f32 -> IEEE 754 binary16, round-to-nearest-even.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x7F_FFFF;
    if exp == 0xFF {
        return sign | 0x7C00 | u16::from(frac != 0) << 9; // inf / NaN
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow -> signed zero
        }
        let frac = frac | 0x80_0000; // implicit leading 1
        let shift = (14 - e) as u32;
        let sub = (frac >> shift) as u16;
        let rem = frac & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let round_up = rem > half || (rem == half && sub & 1 == 1);
        return sign | (sub + u16::from(round_up));
    }
    let out = sign | ((e as u16) << 10) | ((frac >> 13) as u16);
    let rem = frac & 0x1FFF;
    // mantissa carry into the exponent is the correct IEEE rounding
    let round_up = rem > 0x1000 || (rem == 0x1000 && out & 1 == 1);
    out.wrapping_add(u16::from(round_up))
}

// ---------------------------------------------------------------------------
// parsing
// ---------------------------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked: a corrupt length field can put pos + n past usize::MAX,
        // which must be a parse error, not an arithmetic panic
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .with_context(|| format!("truncated GGUF: need {n} bytes at offset {}", self.pos))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u64()? as usize;
        Ok(String::from_utf8_lossy(self.take(len)?).into_owned())
    }
}

/// One metadata value we retain (others are skipped, not lost to
/// parsing — every GGUF value is self-delimiting).
#[derive(Clone, Debug, PartialEq)]
pub enum GgufValue {
    /// Any integer type (u8..u64, i8..i64, bool), widened.
    Int(u64),
    /// f32 or f64, narrowed to f32.
    Float(f32),
    /// A UTF-8 string.
    Str(String),
}

/// Nested-array nesting cap.  Real GGUF files nest at most one level
/// (arrays of scalars); a corrupt file declaring arrays-of-arrays all
/// the way down must hit a parse error, not exhaust the stack.
const MAX_ARRAY_DEPTH: u32 = 8;

fn read_value(c: &mut Cursor, ty: u32, depth: u32) -> Result<Option<GgufValue>> {
    Ok(match ty {
        0 | 1 | 7 => Some(GgufValue::Int(c.take(1)?[0] as u64)), // u8/i8/bool
        2 | 3 => {
            Some(GgufValue::Int(u16::from_le_bytes(c.take(2)?.try_into().unwrap()) as u64))
        }
        4 | 5 => Some(GgufValue::Int(c.u32()? as u64)),
        10 | 11 => Some(GgufValue::Int(c.u64()?)),
        6 => Some(GgufValue::Float(f32::from_le_bytes(c.take(4)?.try_into().unwrap()))),
        12 => {
            Some(GgufValue::Float(
                f64::from_le_bytes(c.take(8)?.try_into().unwrap()) as f32
            ))
        }
        8 => Some(GgufValue::Str(c.string()?)),
        9 => {
            // array: recurse per element to skip (tokenizer vocab etc.)
            if depth >= MAX_ARRAY_DEPTH {
                bail!("GGUF arrays nested deeper than {MAX_ARRAY_DEPTH} levels");
            }
            let elem_ty = c.u32()?;
            let count = c.u64()?;
            for _ in 0..count {
                read_value(c, elem_ty, depth + 1)?;
            }
            None
        }
        other => bail!("unknown GGUF value type {other}"),
    })
}

/// One tensor descriptor. `dims` is in ggml order: `dims[0]` is the
/// contiguous (column) extent, so a matrix stored row-major with our
/// `(rows, cols)` convention has `dims == [cols, rows]`.
#[derive(Clone, Debug)]
pub struct GgufTensorInfo {
    pub name: String,
    pub dims: Vec<usize>,
    pub ggml_type: u32,
    /// Offset into the (aligned) data section.
    pub offset: u64,
}

impl GgufTensorInfo {
    pub fn n_elems(&self) -> usize {
        self.checked_elems().unwrap_or(usize::MAX)
    }

    /// Element count with overflow detection — corrupt dims whose
    /// product exceeds `usize` are a parse error, never a wrap or panic.
    pub fn checked_elems(&self) -> Result<usize> {
        let mut n = 1usize;
        for &d in &self.dims {
            n = n
                .checked_mul(d)
                .with_context(|| format!("tensor {:?} dims {:?} overflow", self.name, self.dims))?;
        }
        Ok(n.max(1))
    }

    /// Encoded byte size of this tensor's data.  Quantized types require
    /// a whole number of blocks: a corrupt extent that is not a multiple
    /// of [`GGML_BLOCK`] is rejected instead of silently truncating the
    /// tail block.
    pub fn data_bytes(&self) -> Result<usize> {
        let n = self.checked_elems()?;
        let blocks = |per_block: usize| -> Result<usize> {
            if n % GGML_BLOCK != 0 {
                bail!(
                    "tensor {:?} has {n} elements (not a multiple of the {GGML_BLOCK}-element \
                     ggml block)",
                    self.name
                );
            }
            (n / GGML_BLOCK)
                .checked_mul(per_block)
                .with_context(|| format!("tensor {:?} byte size overflows", self.name))
        };
        match self.ggml_type {
            GGML_F32 => n
                .checked_mul(4)
                .with_context(|| format!("tensor {:?} byte size overflows", self.name)),
            GGML_F16 => n
                .checked_mul(2)
                .with_context(|| format!("tensor {:?} byte size overflows", self.name)),
            GGML_Q8_0 => blocks(34),
            GGML_Q4_0 => blocks(18),
            GGML_Q5_0 => blocks(22),
            other => bail!("unsupported ggml tensor type {other} for {:?}", self.name),
        }
    }
}

/// A parsed GGUF file: retained metadata, tensor directory, and the raw
/// bytes of the data section.
pub struct Gguf {
    pub version: u32,
    pub alignment: u64,
    pub kv: HashMap<String, GgufValue>,
    pub tensors: Vec<GgufTensorInfo>,
    data: Vec<u8>,
}

impl Gguf {
    pub fn tensor(&self, name: &str) -> Option<&GgufTensorInfo> {
        self.tensors.iter().find(|t| t.name == name)
    }

    fn kv_usize(&self, key: &str) -> Result<usize> {
        match self.kv.get(key) {
            Some(GgufValue::Int(v)) => Ok(*v as usize),
            Some(other) => bail!("GGUF key {key} has non-integer value {other:?}"),
            None => bail!("GGUF metadata missing required key {key}"),
        }
    }

    /// Dequantize one tensor to f32, in storage (row-major) order.
    pub fn dequantize(&self, t: &GgufTensorInfo) -> Result<Vec<f32>> {
        let bytes = t.data_bytes()?;
        let off = usize::try_from(t.offset)
            .ok()
            .and_then(|o| o.checked_add(bytes).map(|end| (o, end)));
        let raw = match off {
            Some((o, end)) if end <= self.data.len() => &self.data[o..end],
            _ => bail!("tensor {:?} data out of range", t.name),
        };
        // data_bytes() succeeded above, so n is overflow-checked and the
        // allocation is bounded by the in-range byte extent just verified
        let n = t.n_elems();
        let mut out = Vec::with_capacity(n);
        match t.ggml_type {
            GGML_F32 => {
                out.extend(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())));
            }
            GGML_F16 => {
                out.extend(
                    raw.chunks_exact(2)
                        .map(|c| f16_to_f32(u16::from_le_bytes(c.try_into().unwrap()))),
                );
            }
            GGML_Q8_0 => {
                for b in raw.chunks_exact(34) {
                    let d = f16_to_f32(u16::from_le_bytes(b[0..2].try_into().unwrap()));
                    out.extend(b[2..34].iter().map(|&q| (q as i8) as f32 * d));
                }
            }
            GGML_Q4_0 => {
                for b in raw.chunks_exact(18) {
                    let d = f16_to_f32(u16::from_le_bytes(b[0..2].try_into().unwrap()));
                    let qs = &b[2..18];
                    // block elements j and j+16 share byte j (low/high nibble)
                    out.extend(qs.iter().map(|&v| ((v & 0x0F) as i32 - 8) as f32 * d));
                    out.extend(qs.iter().map(|&v| ((v >> 4) as i32 - 8) as f32 * d));
                }
            }
            GGML_Q5_0 => {
                for b in raw.chunks_exact(22) {
                    let d = f16_to_f32(u16::from_le_bytes(b[0..2].try_into().unwrap()));
                    let qh = u32::from_le_bytes(b[2..6].try_into().unwrap());
                    let qs = &b[6..22];
                    for (j, &q) in qs.iter().enumerate() {
                        let v = (q & 0x0F) as u32 | ((qh >> j) & 1) << 4;
                        out.push((v as i32 - 16) as f32 * d);
                    }
                    for (j, &q) in qs.iter().enumerate() {
                        let v = (q >> 4) as u32 | ((qh >> (j + 16)) & 1) << 4;
                        out.push((v as i32 - 16) as f32 * d);
                    }
                }
            }
            other => bail!("unsupported ggml tensor type {other}"),
        }
        Ok(out)
    }
}

/// Parse a GGUF v2/v3 file (the whole file is read into memory; model
/// files at this repo's scale are small, and the importer is a one-shot
/// offline tool).
pub fn read_gguf(path: &Path) -> Result<Gguf> {
    let buf = std::fs::read(path).with_context(|| format!("open {path:?}"))?;
    let mut c = Cursor { buf: &buf, pos: 0 };
    if c.take(4)? != b"GGUF" {
        bail!("not a GGUF file (bad magic)");
    }
    let version = c.u32()?;
    if !(2..=3).contains(&version) {
        bail!("unsupported GGUF version {version} (v2/v3 only)");
    }
    let tensor_count = c.u64()? as usize;
    let kv_count = c.u64()? as usize;
    // each kv entry is >= 13 bytes (key length + type + 1-byte value) and
    // each tensor record >= 32; counts implying more records than the file
    // could hold are corruption — reject BEFORE any count-sized allocation
    let remaining = buf.len() - c.pos;
    if kv_count > remaining / 13 {
        bail!("GGUF kv count {kv_count} impossible for a {} byte file", buf.len());
    }
    if tensor_count > remaining / 32 {
        bail!("GGUF tensor count {tensor_count} impossible for a {} byte file", buf.len());
    }
    let mut kv = HashMap::new();
    for _ in 0..kv_count {
        let key = c.string()?;
        let ty = c.u32()?;
        if let Some(v) = read_value(&mut c, ty, 0).with_context(|| format!("key {key:?}"))? {
            kv.insert(key, v);
        }
    }
    let mut tensors = Vec::with_capacity(tensor_count);
    for _ in 0..tensor_count {
        let name = c.string()?;
        let n_dims = c.u32()? as usize;
        if n_dims == 0 || n_dims > 4 {
            bail!("tensor {name:?} has {n_dims} dims");
        }
        let dims: Vec<usize> =
            (0..n_dims).map(|_| c.u64().map(|v| v as usize)).collect::<Result<_>>()?;
        let ggml_type = c.u32()?;
        let offset = c.u64()?;
        tensors.push(GgufTensorInfo { name, dims, ggml_type, offset });
    }
    let alignment = match kv.get("general.alignment") {
        Some(GgufValue::Int(a)) if *a > 0 => *a,
        _ => DEFAULT_ALIGNMENT,
    };
    let data_start = (c.pos as u64).div_ceil(alignment) * alignment;
    if data_start as usize > buf.len() {
        bail!("GGUF data section starts past EOF");
    }
    let data = buf[data_start as usize..].to_vec();
    Ok(Gguf { version, alignment, kv, tensors, data })
}

// ---------------------------------------------------------------------------
// model assembly
// ---------------------------------------------------------------------------

fn fetch(g: &Gguf, name: &str, rows: usize, cols: usize) -> Result<Vec<f32>> {
    let t = g.tensor(name).with_context(|| format!("GGUF tensor {name:?} missing"))?;
    let want = rows
        .checked_mul(cols)
        .with_context(|| format!("model geometry {rows}x{cols} overflows"))?;
    if t.checked_elems()? != want {
        bail!(
            "GGUF tensor {name:?} has {} elements, model geometry wants {rows}x{cols}",
            t.n_elems()
        );
    }
    if cols > 1 && t.dims.first() != Some(&cols) {
        bail!("GGUF tensor {name:?} dims {:?} not laid out as {rows} rows x {cols} cols", t.dims);
    }
    g.dequantize(t)
}

/// Pick the largest supported group size compatible with the geometry
/// (every quantized tensor extent must divide by it; 256 is the paper's
/// choice and the largest we try).
pub fn choose_gs(dim: usize, hidden_dim: usize, vocab: usize) -> Option<usize> {
    [256usize, 128, 64, 32, 16, 8]
        .into_iter()
        .find(|g| dim % g == 0 && hidden_dim % g == 0 && vocab % g == 0)
}

/// Assemble a float model from a parsed GGUF using the standard llama
/// tensor naming (`token_embd`, `blk.N.*`, `output_norm`, `output`).
/// `gs` overrides the group size; otherwise [`choose_gs`] picks one.
pub fn gguf_to_float(g: &Gguf, gs: Option<usize>) -> Result<FloatModel> {
    let dim = g.kv_usize("llama.embedding_length")?;
    let hidden_dim = g.kv_usize("llama.feed_forward_length")?;
    let n_layers = g.kv_usize("llama.block_count")?;
    let n_heads = g.kv_usize("llama.attention.head_count")?;
    let n_kv_heads = match g.kv.get("llama.attention.head_count_kv") {
        Some(GgufValue::Int(v)) => *v as usize,
        _ => n_heads,
    };
    let seq_len = g.kv_usize("llama.context_length")?;
    let emb = g.tensor("token_embd.weight").context("GGUF missing token_embd.weight")?;
    if emb.dims.first() != Some(&dim) || emb.dims.len() != 2 {
        bail!("token_embd.weight dims {:?} inconsistent with dim {dim}", emb.dims);
    }
    let vocab_size = emb.dims[1];
    let gs = match gs {
        Some(g) => g,
        None => choose_gs(dim, hidden_dim, vocab_size).with_context(|| {
            format!("no group size divides dim={dim}/hidden={hidden_dim}/vocab={vocab_size}")
        })?,
    };
    let cfg = LlamaConfig {
        dim,
        hidden_dim,
        n_layers,
        n_heads,
        n_kv_heads,
        vocab_size,
        seq_len,
        gs,
    };
    cfg.validate().map_err(|e| anyhow::anyhow!("GGUF geometry unsupported: {e}"))?;
    let kv_dim = cfg.kv_dim();

    let tok_emb = fetch(g, "token_embd.weight", vocab_size, dim)?;
    let mut layers = Vec::with_capacity(n_layers);
    for i in 0..n_layers {
        let t = |suffix: &str, rows: usize, cols: usize| {
            fetch(g, &format!("blk.{i}.{suffix}.weight"), rows, cols)
        };
        layers.push(FloatLayer {
            att_norm: t("attn_norm", dim, 1)?,
            wq: t("attn_q", dim, dim)?,
            wk: t("attn_k", kv_dim, dim)?,
            wv: t("attn_v", kv_dim, dim)?,
            wo: t("attn_output", dim, dim)?,
            ffn_norm: t("ffn_norm", dim, 1)?,
            w1: t("ffn_gate", hidden_dim, dim)?,
            w2: t("ffn_down", dim, hidden_dim)?,
            w3: t("ffn_up", hidden_dim, dim)?,
        });
    }
    let final_norm = fetch(g, "output_norm.weight", dim, 1)?;
    // tied embeddings: many llama GGUFs omit output.weight entirely
    let cls = if g.tensor("output.weight").is_some() {
        fetch(g, "output.weight", vocab_size, dim)?
    } else {
        tok_emb.clone()
    };
    Ok(FloatModel { cfg, tok_emb, layers, final_norm, cls })
}

/// Import a GGUF checkpoint into a native quantized checkpoint in
/// format `fmt`: dequantize every tensor to f32, then re-quantize on
/// the model's own group lattice through [`super::write_ckpt_from_float`].
/// Returns the imported model's config.
pub fn import_gguf(
    gguf_path: &Path,
    out_path: &Path,
    fmt: FormatId,
    gs: Option<usize>,
) -> Result<LlamaConfig> {
    let g = read_gguf(gguf_path)?;
    let fm = gguf_to_float(&g, gs)?;
    super::write_ckpt_from_float(out_path, &fm, fmt)?;
    Ok(fm.cfg)
}

// ---------------------------------------------------------------------------
// test/export writer — enough GGUF to round-trip our own models
// ---------------------------------------------------------------------------

fn ggml_quantize_block(chunk: &[f32], ggml_type: u32, out: &mut Vec<u8>) {
    let qmax = match ggml_type {
        GGML_Q8_0 => 127i32,
        GGML_Q4_0 => 7,
        GGML_Q5_0 => 15,
        _ => unreachable!(),
    };
    let amax = chunk.iter().fold(0f32, |m, &v| m.max(v.abs()));
    let d = if amax == 0.0 { 0.0 } else { amax / qmax as f32 };
    let inv = if d == 0.0 { 0.0 } else { 1.0 / d };
    let q: Vec<i32> =
        chunk.iter().map(|&v| (v * inv).round().clamp(-qmax as f32, qmax as f32) as i32).collect();
    out.extend_from_slice(&f32_to_f16(d).to_le_bytes());
    match ggml_type {
        GGML_Q8_0 => out.extend(q.iter().map(|&v| v as i8 as u8)),
        GGML_Q4_0 => {
            for j in 0..16 {
                out.push(((q[j] + 8) as u8 & 0x0F) | (((q[j + 16] + 8) as u8 & 0x0F) << 4));
            }
        }
        GGML_Q5_0 => {
            let mut qh = 0u32;
            for (j, &v) in q.iter().enumerate() {
                qh |= ((((v + 16) as u32) >> 4) & 1) << j;
            }
            out.extend_from_slice(&qh.to_le_bytes());
            for j in 0..16 {
                out.push(((q[j] + 16) as u8 & 0x0F) | (((q[j + 16] + 16) as u8 & 0x0F) << 4));
            }
        }
        _ => unreachable!(),
    }
}

fn encode_tensor(data: &[f32], ggml_type: u32) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    match ggml_type {
        GGML_F32 => {
            for &v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        GGML_F16 => {
            for &v in data {
                out.extend_from_slice(&f32_to_f16(v).to_le_bytes());
            }
        }
        GGML_Q8_0 | GGML_Q4_0 | GGML_Q5_0 => {
            anyhow::ensure!(
                data.len() % GGML_BLOCK == 0,
                "quantized ggml tensors need a multiple of {GGML_BLOCK} elements"
            );
            for chunk in data.chunks_exact(GGML_BLOCK) {
                ggml_quantize_block(chunk, ggml_type, &mut out);
            }
        }
        other => bail!("unsupported ggml type {other}"),
    }
    Ok(out)
}

/// Write a minimal valid GGUF v3 file from a float model, encoding
/// matrices in `ggml_type` (norm vectors stay F32, as real exporters
/// do).  This exists for round-trip testing of the importer; it is not
/// a general GGUF exporter.
/// (name, dims in ggml order, ggml type, float data) — writer work list.
type TensorEntry<'a> = (String, Vec<usize>, u32, &'a [f32]);

pub fn write_gguf_from_float(path: &Path, fm: &FloatModel, ggml_type: u32) -> Result<()> {
    let cfg = fm.cfg;
    let kv_dim = cfg.kv_dim();
    let mut tensors: Vec<TensorEntry> = vec![(
        "token_embd.weight".into(),
        vec![cfg.dim, cfg.vocab_size],
        ggml_type,
        &fm.tok_emb,
    )];
    for (i, l) in fm.layers.iter().enumerate() {
        tensors.push((format!("blk.{i}.attn_norm.weight"), vec![cfg.dim], GGML_F32, &l.att_norm));
        tensors.push((format!("blk.{i}.attn_q.weight"), vec![cfg.dim, cfg.dim], ggml_type, &l.wq));
        tensors.push((format!("blk.{i}.attn_k.weight"), vec![cfg.dim, kv_dim], ggml_type, &l.wk));
        tensors.push((format!("blk.{i}.attn_v.weight"), vec![cfg.dim, kv_dim], ggml_type, &l.wv));
        tensors.push((
            format!("blk.{i}.attn_output.weight"),
            vec![cfg.dim, cfg.dim],
            ggml_type,
            &l.wo,
        ));
        tensors.push((format!("blk.{i}.ffn_norm.weight"), vec![cfg.dim], GGML_F32, &l.ffn_norm));
        tensors.push((
            format!("blk.{i}.ffn_gate.weight"),
            vec![cfg.dim, cfg.hidden_dim],
            ggml_type,
            &l.w1,
        ));
        tensors.push((
            format!("blk.{i}.ffn_down.weight"),
            vec![cfg.hidden_dim, cfg.dim],
            ggml_type,
            &l.w2,
        ));
        tensors.push((
            format!("blk.{i}.ffn_up.weight"),
            vec![cfg.dim, cfg.hidden_dim],
            ggml_type,
            &l.w3,
        ));
    }
    tensors.push(("output_norm.weight".into(), vec![cfg.dim], GGML_F32, &fm.final_norm));
    tensors.push(("output.weight".into(), vec![cfg.dim, cfg.vocab_size], ggml_type, &fm.cls));

    let mut head = Vec::new();
    head.extend_from_slice(b"GGUF");
    head.extend_from_slice(&3u32.to_le_bytes());
    head.extend_from_slice(&(tensors.len() as u64).to_le_bytes());
    let kvs: [(&str, u64); 6] = [
        ("llama.embedding_length", cfg.dim as u64),
        ("llama.feed_forward_length", cfg.hidden_dim as u64),
        ("llama.block_count", cfg.n_layers as u64),
        ("llama.attention.head_count", cfg.n_heads as u64),
        ("llama.attention.head_count_kv", cfg.n_kv_heads as u64),
        ("llama.context_length", cfg.seq_len as u64),
    ];
    head.extend_from_slice(&(kvs.len() as u64).to_le_bytes());
    for (k, v) in kvs {
        head.extend_from_slice(&(k.len() as u64).to_le_bytes());
        head.extend_from_slice(k.as_bytes());
        head.extend_from_slice(&4u32.to_le_bytes()); // u32 value
        head.extend_from_slice(&(v as u32).to_le_bytes());
    }
    // encode data first so tensor offsets are known
    let mut data = Vec::new();
    let mut infos = Vec::new();
    for (name, dims, ty, payload) in &tensors {
        // every tensor starts aligned inside the data section
        while data.len() % DEFAULT_ALIGNMENT as usize != 0 {
            data.push(0);
        }
        infos.push((name.clone(), dims.clone(), *ty, data.len() as u64));
        data.extend(encode_tensor(payload, *ty)?);
    }
    for (name, dims, ty, offset) in infos {
        head.extend_from_slice(&(name.len() as u64).to_le_bytes());
        head.extend_from_slice(name.as_bytes());
        head.extend_from_slice(&(dims.len() as u32).to_le_bytes());
        for d in dims {
            head.extend_from_slice(&(d as u64).to_le_bytes());
        }
        head.extend_from_slice(&ty.to_le_bytes());
        head.extend_from_slice(&offset.to_le_bytes());
    }
    while head.len() % DEFAULT_ALIGNMENT as usize != 0 {
        head.push(0);
    }
    head.extend_from_slice(&data);
    std::fs::write(path, head).with_context(|| format!("write {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    #[test]
    fn f16_roundtrip_exact_for_representable() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 65504.0, -65504.0, 1.0 / 1024.0, 0.099975586] {
            assert_eq!(f16_to_f32(f32_to_f16(v)), v, "{v}");
        }
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f32_to_f16(1e9)), f32::INFINITY); // overflow
        // subnormal survives
        let tiny = f16_to_f32(1); // smallest positive f16 subnormal
        assert!(tiny > 0.0);
        assert_eq!(f32_to_f16(tiny), 1);
    }

    #[test]
    fn f16_conversion_error_bounded() {
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..1000 {
            let v = rng.next_f32() * 2.0 - 1.0;
            let r = f16_to_f32(f32_to_f16(v));
            // half has 11 significand bits: relative error <= 2^-11
            assert!((r - v).abs() <= v.abs() * (1.0 / 2048.0) + 1e-12, "{v} -> {r}");
        }
    }

    #[test]
    fn gguf_roundtrip_every_type() {
        let fm = FloatModel::random(tiny_cfg(), 31);
        let cases = [
            (GGML_F32, 0.0f32),
            (GGML_F16, 1.0 / 2048.0),
            (GGML_Q8_0, 1.0 / 127.0),
            (GGML_Q4_0, 1.0 / 7.0),
            (GGML_Q5_0, 1.0 / 15.0),
        ];
        for (ty, tol_scale) in cases {
            let path = std::env::temp_dir().join(format!("llamaf_test_gguf_{ty}.gguf"));
            write_gguf_from_float(&path, &fm, ty).unwrap();
            let g = read_gguf(&path).unwrap();
            assert_eq!(g.version, 3);
            let fm2 = gguf_to_float(&g, None).unwrap();
            assert_eq!(fm2.cfg, fm.cfg);
            // norms are always F32: exact for every matrix type
            assert_eq!(fm2.layers[0].att_norm, fm.layers[0].att_norm);
            assert_eq!(fm2.final_norm, fm.final_norm);
            if ty == GGML_F32 {
                assert_eq!(fm2.tok_emb, fm.tok_emb);
                assert_eq!(fm2.layers[1].w2, fm.layers[1].w2);
            } else {
                // block quantization: per-element error <= step size, where
                // step = block_absmax / qmax; 4.5 sigma bounds the absmax
                // of N(0, 0.02) blocks, f16 scale rounding adds ~2^-11
                let tol = 0.02 * 4.5 * tol_scale * 1.01 + 1e-6;
                for (a, b) in fm.layers[1].w2.iter().zip(&fm2.layers[1].w2) {
                    assert!((a - b).abs() <= tol, "{ty}: {a} vs {b}");
                }
            }
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn tied_embeddings_fall_back_to_token_embd() {
        let fm = FloatModel::random(tiny_cfg(), 32);
        let path = std::env::temp_dir().join("llamaf_test_gguf_tied.gguf");
        write_gguf_from_float(&path, &fm, GGML_F32).unwrap();
        // strip output.weight by rewriting without it: easier — parse and
        // check the fallback path directly on a file that HAS the tensor,
        // then on a synthetic Gguf with it removed
        let mut g = read_gguf(&path).unwrap();
        g.tensors.retain(|t| t.name != "output.weight");
        let fm2 = gguf_to_float(&g, None).unwrap();
        assert_eq!(fm2.cls, fm2.tok_emb);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn import_f32_gguf_is_bit_identical_to_native_quantization() {
        use crate::model::QuantModel;
        let fm = FloatModel::random(tiny_cfg(), 33);
        let gguf = std::env::temp_dir().join("llamaf_test_import.gguf");
        write_gguf_from_float(&gguf, &fm, GGML_F32).unwrap();
        for fmt in FormatId::ALL {
            let out = std::env::temp_dir().join(format!("llamaf_test_import_{}.lfq", fmt.name()));
            let cfg = import_gguf(&gguf, &out, fmt, None).unwrap();
            assert_eq!(cfg, fm.cfg);
            let imported = super::super::read_ckpt(&out).unwrap();
            let native = QuantModel::from_float_fmt(&fm, fmt);
            assert_eq!(imported.tok_emb, native.tok_emb, "{fmt}");
            assert_eq!(imported.layers[0].wqkv, native.layers[0].wqkv, "{fmt}");
            assert_eq!(imported.cls, native.cls, "{fmt}");
            std::fs::remove_file(out).ok();
        }
        std::fs::remove_file(gguf).ok();
    }

    #[test]
    fn choose_gs_prefers_largest() {
        assert_eq!(choose_gs(2048, 5632, 32000), Some(256));
        assert_eq!(choose_gs(64, 128, 64), Some(64));
        assert_eq!(choose_gs(48, 96, 48), Some(16));
        assert_eq!(choose_gs(7, 7, 7), None);
    }

    #[test]
    fn truncated_and_bad_magic_rejected() {
        let path = std::env::temp_dir().join("llamaf_test_gguf_bad.gguf");
        std::fs::write(&path, b"GGML").unwrap();
        assert!(read_gguf(&path).is_err());
        std::fs::write(&path, b"GGUF\x03\x00\x00\x00").unwrap();
        assert!(read_gguf(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    /// Mutation corpus: seeded byte flips and truncations of a valid
    /// GGUF must come back as `Ok` or `Err` — never a panic, hang, or
    /// count-sized allocation.  (Runs in-process: any panic fails the
    /// test; an unchecked `Vec::with_capacity` from a flipped length
    /// field would abort the runner.)
    #[test]
    fn mutation_corpus_gguf_reader_never_panics() {
        let fm = FloatModel::random(tiny_cfg(), 35);
        let path = std::env::temp_dir().join("llamaf_test_gguf_mutate.gguf");
        write_gguf_from_float(&path, &fm, GGML_Q4_0).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let mut rng = crate::util::Rng::new(0xFA01);
        let mut survived = 0usize;
        for i in 0..300 {
            let mut bad = clean.clone();
            match i % 3 {
                0 => {
                    // single-byte flip (XOR with a nonzero mask: always a change)
                    let pos = rng.below(bad.len() as u64) as usize;
                    bad[pos] ^= rng.below(255) as u8 + 1;
                }
                1 => {
                    bad.truncate(rng.below(bad.len() as u64) as usize);
                }
                _ => {
                    // burst of flips, biased toward the header/directory
                    for _ in 0..8 {
                        let pos = rng.below(bad.len().min(512) as u64) as usize;
                        bad[pos] ^= rng.below(255) as u8 + 1;
                    }
                }
            }
            std::fs::write(&path, &bad).unwrap();
            // either outcome is fine; returning at all is the assertion
            if let Ok(g) = read_gguf(&path) {
                if gguf_to_float(&g, None).is_ok() {
                    survived += 1; // flip landed in padding or tensor data
                }
            }
        }
        // sanity: the corpus must actually exercise the error paths
        assert!(survived < 150, "corpus too tame: {survived}/300 parsed clean");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn implausible_counts_rejected_before_allocation() {
        // hand-build a header claiming 2^60 tensors: must bail on the
        // count check, not die inside Vec::with_capacity
        let mut buf = Vec::new();
        buf.extend_from_slice(b"GGUF");
        buf.extend_from_slice(&3u32.to_le_bytes());
        buf.extend_from_slice(&(1u64 << 60).to_le_bytes()); // tensor count
        buf.extend_from_slice(&0u64.to_le_bytes()); // kv count
        let path = std::env::temp_dir().join("llamaf_test_gguf_bigcount.gguf");
        std::fs::write(&path, &buf).unwrap();
        let err = format!("{:#}", read_gguf(&path).unwrap_err());
        assert!(err.contains("impossible"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn unknown_ggml_type_reported() {
        let fm = FloatModel::random(tiny_cfg(), 34);
        let path = std::env::temp_dir().join("llamaf_test_gguf_q2.gguf");
        assert!(write_gguf_from_float(&path, &fm, 99).is_err());
        std::fs::remove_file(path).ok();
    }
}
