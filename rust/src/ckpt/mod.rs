//! Checkpoint I/O — bit-for-bit mirror of `python/compile/quantize.py`
//! for INT8, generalized over [`FormatId`] for sub-INT8 formats.
//!
//! Magics: `LFCK` = float32 checkpoint; `LFQ8` / `LFQ4` / `LFQ5` =
//! group-quantized checkpoints in the corresponding [`FormatId`] wire
//! encoding.  Layout (little-endian): 4-byte magic, 9×u32 header
//! (version, dim, hidden_dim, n_layers, n_heads, n_kv_heads,
//! vocab_size, seq_len, gs), then tensors in a fixed order grouped
//! *per layer* — the grouping is what allows the engine to stream one
//! layer (or one matrix) at a time from "DDR" (paper §III-B) instead of
//! keeping all weights resident.
//!
//! Quantized tensors are stored as the format's packed payload
//! (row-major groups, see [`crate::quant::PackedTensor`]) followed by
//! f32 group scales.  For `LFQ8` the payload is raw int8 — byte-for-
//! byte the historical format, pinned by
//! `layer_and_matrix_offsets_pin_written_byte_layout`.
//!
//! All offset/byte arithmetic lives in [`CkptLayout`]; the historical
//! `q8_*` free functions remain one PR as deprecated wrappers.
//!
//! Quantized checkpoints written by this crate additionally carry an
//! **integrity footer** after the content: per-segment CRC-32 checksums
//! (one per staging unit — embeddings, every layer × [`MatrixUnit`],
//! final norm + classifier) so corruption is caught **at staging time**,
//! before bad bytes ever reach a kernel.  Files without the footer
//! (older writers, hand-built fixtures) still load, flagged
//! `unverified` ([`CkptSource::verified`]); `llamaf verify-ckpt` runs
//! the same pass offline ([`verify_ckpt`]).

pub mod crc;
pub mod gguf;

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::{
    FloatLayer, FloatModel, LayerChunk, LlamaConfig, MatrixUnit, QuantLayer, QuantModel,
};
use crate::quant::{FormatId, PackedTensor, QuantizedTensor};

pub const MAGIC_F32: &[u8; 4] = b"LFCK";
pub const MAGIC_Q8: &[u8; 4] = b"LFQ8";
pub const VERSION: u32 = 1;
pub const HEADER_BYTES: u64 = 40;

/// Magic of the integrity footer appended after the checkpoint content
/// ("LlamaF CheckSums").
pub const FOOTER_MAGIC: &[u8; 4] = b"LFCS";
/// Integrity-footer format version.
pub const FOOTER_VERSION: u32 = 1;

// ---------------------------------------------------------------------------
// header
// ---------------------------------------------------------------------------

fn read_header_body(r: &mut impl Read) -> Result<LlamaConfig> {
    let mut buf = [0u8; 36];
    r.read_exact(&mut buf).context("reading header")?;
    let u = |i: usize| u32::from_le_bytes(buf[4 * i..4 * i + 4].try_into().unwrap()) as usize;
    let version = u(0);
    if version != VERSION as usize {
        bail!("unsupported checkpoint version {version}");
    }
    let cfg = LlamaConfig {
        dim: u(1),
        hidden_dim: u(2),
        n_layers: u(3),
        n_heads: u(4),
        n_kv_heads: u(5),
        vocab_size: u(6),
        seq_len: u(7),
        gs: u(8),
    };
    cfg.validate().map_err(|e| anyhow::anyhow!("invalid config in header: {e}"))?;
    Ok(cfg)
}

fn read_header(r: &mut impl Read, magic: &[u8; 4]) -> Result<LlamaConfig> {
    let mut m = [0u8; 4];
    r.read_exact(&mut m).context("reading magic")?;
    if &m != magic {
        bail!(
            "bad magic {:?} (expected {:?})",
            String::from_utf8_lossy(&m),
            String::from_utf8_lossy(magic)
        );
    }
    read_header_body(r)
}

/// Read the header of a quantized checkpoint in ANY supported format,
/// identifying the format from the magic.
fn read_quant_header(r: &mut impl Read) -> Result<(LlamaConfig, FormatId)> {
    let mut m = [0u8; 4];
    r.read_exact(&mut m).context("reading magic")?;
    let fmt = FormatId::from_magic(&m).with_context(|| {
        format!("bad magic {:?} (expected a quantized checkpoint)", String::from_utf8_lossy(&m))
    })?;
    Ok((read_header_body(r)?, fmt))
}

fn write_header(w: &mut impl Write, magic: &[u8; 4], cfg: &LlamaConfig) -> Result<()> {
    w.write_all(magic)?;
    for v in [
        VERSION as usize,
        cfg.dim,
        cfg.hidden_dim,
        cfg.n_layers,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.vocab_size,
        cfg.seq_len,
        cfg.gs,
    ] {
        w.write_all(&(v as u32).to_le_bytes())?;
    }
    Ok(())
}

/// Peek only the config of a checkpoint file: `(cfg, None)` for a float
/// `LFCK` file, `(cfg, Some(fmt))` for a quantized one.
pub fn peek_config(path: &Path) -> Result<(LlamaConfig, Option<FormatId>)> {
    let mut f = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let mut m = [0u8; 4];
    f.read_exact(&mut m)?;
    f.seek(SeekFrom::Start(0))?;
    if &m == MAGIC_F32 {
        Ok((read_header(&mut f, MAGIC_F32)?, None))
    } else {
        let (cfg, fmt) = read_quant_header(&mut f)?;
        Ok((cfg, Some(fmt)))
    }
}

// ---------------------------------------------------------------------------
// primitive readers/writers
// ---------------------------------------------------------------------------

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes).context("reading f32 tensor")?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn write_f32s(w: &mut impl Write, data: &[f32]) -> Result<()> {
    for &v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read one quantized tensor: the format's packed payload, then one f32
/// scale per group.  Unpacks into the i8 compute form on the way in (the
/// host-sim analogue of the FPGA's post-DDR nibble-unpack stage).
fn read_quant(
    r: &mut impl Read,
    rows: usize,
    cols: usize,
    gs: usize,
    fmt: FormatId,
) -> Result<QuantizedTensor> {
    let groups = rows * cols / gs;
    let mut data = vec![0u8; groups * fmt.format().group_payload_bytes(gs)];
    r.read_exact(&mut data).context("reading quantized payload")?;
    let s = read_f32s(r, groups)?;
    Ok(PackedTensor { fmt, data, s, rows, cols, gs }.unpack())
}

/// Write one quantized tensor in its format's wire encoding.
fn write_quant(w: &mut impl Write, t: &QuantizedTensor) -> Result<()> {
    let p = PackedTensor::pack(t);
    w.write_all(&p.data)?;
    write_f32s(w, &p.s)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// CkptLayout — offsets and byte counts, computed from the format
// ---------------------------------------------------------------------------

/// Byte layout of a quantized checkpoint: every offset and length the
/// streaming path needs, computed from the [`FormatId`]'s wire encoding
/// so matrix-granular staging and the staging ring work for every
/// format unchanged (PR 5's `q8_*` free functions, generalized).
#[derive(Clone, Copy, Debug)]
pub struct CkptLayout {
    /// Model geometry (from the checkpoint header).
    pub cfg: LlamaConfig,
    /// Wire format of every quantized tensor in the file.
    pub fmt: FormatId,
}

impl CkptLayout {
    /// Layout of a `cfg`-geometry checkpoint in format `fmt`.
    pub fn new(cfg: LlamaConfig, fmt: FormatId) -> CkptLayout {
        CkptLayout { cfg, fmt }
    }

    /// On-disk bytes of one `rows × cols` quantized tensor (packed
    /// payload + f32 scales).
    pub fn tensor_bytes(&self, rows: usize, cols: usize) -> u64 {
        self.fmt.format().bytes_for(rows, cols, self.cfg.gs) as u64
    }

    /// Byte size of one layer block.
    pub fn layer_bytes(&self) -> u64 {
        let (d, h, kv) = (self.cfg.dim, self.cfg.hidden_dim, self.cfg.kv_dim());
        4 * d as u64 // att_norm
            + self.tensor_bytes(d, d) // wq
            + 2 * self.tensor_bytes(kv, d) // wk wv
            + self.tensor_bytes(d, d) // wo
            + 4 * d as u64 // ffn_norm
            + 2 * self.tensor_bytes(h, d) // w1 w3
            + self.tensor_bytes(d, h) // w2
    }

    /// File offset of layer `layer`'s block.
    pub fn layer_offset(&self, layer: usize) -> u64 {
        HEADER_BYTES
            + self.tensor_bytes(self.cfg.vocab_size, self.cfg.dim)
            + layer as u64 * self.layer_bytes()
    }

    /// On-disk byte segments `(absolute_offset, length)` of one
    /// matrix-granular staging unit inside layer `layer`'s block.
    ///
    /// Most units are one contiguous segment; two span a pair because of
    /// the fixed tensor order (`att_norm wq wk wv wo ffn_norm w1 w2
    /// w3`): [`MatrixUnit::Norms`] covers `att_norm` + `ffn_norm`, and
    /// [`MatrixUnit::W13`] covers `w1` + `w3` (the on-disk layout
    /// interleaves `w2` between them).  Across all five units the
    /// segments are disjoint and tile the layer block exactly — pinned
    /// by unit tests against the bytes [`write_ckpt_from_float`]
    /// actually writes.
    pub fn matrix_segments(&self, layer: usize, unit: MatrixUnit) -> Vec<(u64, u64)> {
        let (d, h, kv) = (self.cfg.dim, self.cfg.hidden_dim, self.cfg.kv_dim());
        let base = self.layer_offset(layer);
        let norm = 4 * d as u64;
        let dd = self.tensor_bytes(d, d); // wq / wo
        let kvd = self.tensor_bytes(kv, d); // wk / wv
        let hd = self.tensor_bytes(h, d); // w1 / w3
        let dh = self.tensor_bytes(d, h); // w2
        let wq_off = base + norm;
        let wo_off = wq_off + dd + 2 * kvd;
        let ffn_off = wo_off + dd;
        let w1_off = ffn_off + norm;
        let w2_off = w1_off + hd;
        let w3_off = w2_off + dh;
        match unit {
            MatrixUnit::Norms => vec![(base, norm), (ffn_off, norm)],
            MatrixUnit::Qkv => vec![(wq_off, dd + 2 * kvd)],
            MatrixUnit::Wo => vec![(wo_off, dd)],
            MatrixUnit::W13 => vec![(w1_off, hd), (w3_off, hd)],
            MatrixUnit::W2 => vec![(w2_off, dh)],
        }
    }

    /// Absolute file offset of `unit`'s first on-disk segment in layer
    /// `layer` (see [`CkptLayout::matrix_segments`] for the units that
    /// span two segments).
    pub fn matrix_offset(&self, layer: usize, unit: MatrixUnit) -> u64 {
        self.matrix_segments(layer, unit)[0].0
    }

    /// Total on-disk bytes of one matrix-granular unit (all segments).
    pub fn matrix_bytes(&self, unit: MatrixUnit) -> u64 {
        self.matrix_segments(0, unit).iter().map(|&(_, len)| len).sum()
    }

    /// Total *content* size of the checkpoint: header, embeddings, every
    /// layer block, final norm, classifier — excluding the integrity
    /// footer ([`CkptLayout::file_bytes`] includes it).
    pub fn total_bytes(&self) -> u64 {
        self.layer_offset(self.cfg.n_layers)
            + 4 * self.cfg.dim as u64
            + self.tensor_bytes(self.cfg.vocab_size, self.cfg.dim)
    }

    /// Number of checksummed segments in the integrity footer: the
    /// embedding block, one entry per layer × [`MatrixUnit`] (the
    /// staging units, so staging-time verification needs exactly one
    /// checksum per fetch), and the final-norm + classifier tail.
    pub fn checksum_count(&self) -> usize {
        2 + self.cfg.n_layers * crate::model::MATRIX_UNITS.len()
    }

    /// Byte size of the integrity footer: magic + version + count +
    /// one u32 CRC per segment + the footer's own CRC.
    pub fn footer_bytes(&self) -> u64 {
        16 + 4 * self.checksum_count() as u64
    }

    /// Total file size *with* the integrity footer appended.
    pub fn file_bytes(&self) -> u64 {
        self.total_bytes() + self.footer_bytes()
    }

    /// Footer index of layer `layer`'s `unit` checksum.
    pub fn checksum_index(&self, layer: usize, unit: MatrixUnit) -> usize {
        1 + layer * crate::model::MATRIX_UNITS.len() + unit.index()
    }

    /// On-disk byte segments covered by footer entry `index` (the
    /// concatenation of the segments is what the CRC runs over).
    pub fn checksum_segments(&self, index: usize) -> Vec<(u64, u64)> {
        let upl = crate::model::MATRIX_UNITS.len();
        if index == 0 {
            // entry 0 starts at byte 0 so the header itself is covered:
            // a header flip that leaves the implied file length unchanged
            // (e.g. seq_len) would otherwise evade both the length gate
            // and every content CRC
            vec![(0, HEADER_BYTES + self.tensor_bytes(self.cfg.vocab_size, self.cfg.dim))]
        } else if index == self.checksum_count() - 1 {
            vec![(
                self.layer_offset(self.cfg.n_layers),
                4 * self.cfg.dim as u64 + self.tensor_bytes(self.cfg.vocab_size, self.cfg.dim),
            )]
        } else {
            let layer = (index - 1) / upl;
            let unit = crate::model::MATRIX_UNITS[(index - 1) % upl];
            self.matrix_segments(layer, unit)
        }
    }

    /// Human-readable name of footer entry `index` for error messages.
    pub fn checksum_label(&self, index: usize) -> String {
        let upl = crate::model::MATRIX_UNITS.len();
        if index == 0 {
            "header+tok_emb".into()
        } else if index == self.checksum_count() - 1 {
            "final_norm+cls".into()
        } else {
            let layer = (index - 1) / upl;
            let unit = crate::model::MATRIX_UNITS[(index - 1) % upl];
            format!("layer {layer} ({})", unit.name())
        }
    }
}

// ---------------------------------------------------------------------------
// integrity footer — per-segment CRC-32 after the content
// ---------------------------------------------------------------------------

/// The integrity footer of a quantized checkpoint: one CRC-32 per
/// staging segment (see [`CkptLayout::checksum_segments`]).  On-disk
/// encoding, little-endian, appended at [`CkptLayout::total_bytes`]:
/// `LFCS` magic, u32 version, u32 count, `count` × u32 CRCs, then the
/// CRC-32 of the preceding footer bytes (so a corrupted footer is
/// detected rather than trusted).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CkptFooter {
    /// Per-segment CRC-32s, indexed by [`CkptLayout::checksum_index`].
    pub crcs: Vec<u32>,
}

impl CkptFooter {
    /// Serialize to the on-disk encoding (including the self-CRC).
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + 4 * self.crcs.len());
        out.extend_from_slice(FOOTER_MAGIC);
        out.extend_from_slice(&FOOTER_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.crcs.len() as u32).to_le_bytes());
        for &c in &self.crcs {
            out.extend_from_slice(&c.to_le_bytes());
        }
        let self_crc = crc::crc32(&out);
        out.extend_from_slice(&self_crc.to_le_bytes());
        out
    }

    /// Parse and validate a footer read from disk.
    fn from_bytes(buf: &[u8], expected_count: usize) -> Result<CkptFooter> {
        if buf.len() != 16 + 4 * expected_count {
            bail!("integrity footer is {} bytes (expected {})", buf.len(), 16 + 4 * expected_count);
        }
        if &buf[0..4] != FOOTER_MAGIC {
            bail!(
                "bad footer magic {:?} (expected {:?})",
                String::from_utf8_lossy(&buf[0..4]),
                String::from_utf8_lossy(FOOTER_MAGIC)
            );
        }
        let u = |i: usize| u32::from_le_bytes(buf[i..i + 4].try_into().unwrap());
        if u(4) != FOOTER_VERSION {
            bail!("unsupported footer version {}", u(4));
        }
        let count = u(8) as usize;
        if count != expected_count {
            bail!("footer carries {count} checksums (layout expects {expected_count})");
        }
        let self_crc = u(buf.len() - 4);
        let computed = crc::crc32(&buf[..buf.len() - 4]);
        if self_crc != computed {
            bail!("integrity footer is itself corrupt (footer CRC mismatch)");
        }
        let crcs = (0..count).map(|i| u(12 + 4 * i)).collect();
        Ok(CkptFooter { crcs })
    }
}

/// CRC-32 over the concatenation of `segs`, streamed from `file`
/// through a fixed buffer (segments can be hundreds of MB at scale).
fn crc_of_segments(file: &mut File, segs: &[(u64, u64)]) -> Result<u32> {
    let mut c = crc::Crc32::new();
    let mut buf = vec![0u8; 1 << 16];
    for &(off, len) in segs {
        file.seek(SeekFrom::Start(off))?;
        let mut left = len;
        while left > 0 {
            let n = (buf.len() as u64).min(left) as usize;
            file.read_exact(&mut buf[..n]).context("reading checksummed segment")?;
            c.update(&buf[..n]);
            left -= n as u64;
        }
    }
    Ok(c.finish())
}

/// Compute the full integrity footer of `path`'s content by streaming
/// every checksummed segment, then append it.  The file must be exactly
/// [`CkptLayout::total_bytes`] long (content only, no footer yet).
pub fn append_footer(path: &Path) -> Result<()> {
    let (cfg, fmt) = match peek_config(path)? {
        (cfg, Some(fmt)) => (cfg, fmt),
        _ => bail!("only quantized checkpoints carry integrity footers"),
    };
    let layout = CkptLayout::new(cfg, fmt);
    let len = std::fs::metadata(path)?.len();
    if len != layout.total_bytes() {
        bail!(
            "cannot append footer: {path:?} is {len} bytes (expected content of {})",
            layout.total_bytes()
        );
    }
    let mut file = File::open(path)?;
    let mut crcs = Vec::with_capacity(layout.checksum_count());
    for i in 0..layout.checksum_count() {
        crcs.push(crc_of_segments(&mut file, &layout.checksum_segments(i))?);
    }
    drop(file);
    let footer = CkptFooter { crcs };
    let mut w = std::fs::OpenOptions::new().append(true).open(path)?;
    w.write_all(&footer.to_bytes())?;
    w.flush()?;
    Ok(())
}

/// Outcome of an offline integrity pass ([`verify_ckpt`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VerifyOutcome {
    /// The file predates integrity footers: nothing to verify against.
    NoFooter,
    /// Every checksummed segment matched its footer CRC.
    Ok {
        /// Number of segments verified.
        segments: usize,
    },
}

/// Offline integrity pass: stream every checksummed segment of `path`
/// and compare against the footer, naming the first corrupt segment.
/// `llamaf verify-ckpt` is a thin CLI wrapper over this.
pub fn verify_ckpt(path: &Path) -> Result<VerifyOutcome> {
    let mut src = CkptSource::open(path)?;
    let layout = src.layout();
    let Some(footer) = src.footer.clone() else {
        return Ok(VerifyOutcome::NoFooter);
    };
    for i in 0..layout.checksum_count() {
        let got = crc_of_segments(&mut src.file, &layout.checksum_segments(i))?;
        if got != footer.crcs[i] {
            bail!(
                "checksum mismatch in {} (segment {i}: stored {:08x}, computed {got:08x})",
                layout.checksum_label(i),
                footer.crcs[i]
            );
        }
    }
    Ok(VerifyOutcome::Ok { segments: layout.checksum_count() })
}

// ---------------------------------------------------------------------------
// deprecated q8_* wrappers (one PR of grace for external call sites)
// ---------------------------------------------------------------------------

/// Byte size of one LFQ8 layer block.
#[deprecated(note = "use CkptLayout::new(cfg, FormatId::Q8).layer_bytes()")]
pub fn q8_layer_bytes(cfg: &LlamaConfig) -> u64 {
    CkptLayout::new(*cfg, FormatId::Q8).layer_bytes()
}

/// File offset of layer `l`'s block in an LFQ8 file.
#[deprecated(note = "use CkptLayout::new(cfg, FormatId::Q8).layer_offset(layer)")]
pub fn q8_layer_offset(cfg: &LlamaConfig, layer: usize) -> u64 {
    CkptLayout::new(*cfg, FormatId::Q8).layer_offset(layer)
}

/// On-disk byte segments of one matrix-granular unit in an LFQ8 file.
#[deprecated(note = "use CkptLayout::new(cfg, FormatId::Q8).matrix_segments(layer, unit)")]
pub fn q8_matrix_segments(cfg: &LlamaConfig, layer: usize, unit: MatrixUnit) -> Vec<(u64, u64)> {
    CkptLayout::new(*cfg, FormatId::Q8).matrix_segments(layer, unit)
}

/// Absolute file offset of `unit`'s first segment in an LFQ8 file.
#[deprecated(note = "use CkptLayout::new(cfg, FormatId::Q8).matrix_offset(layer, unit)")]
pub fn q8_matrix_offset(cfg: &LlamaConfig, layer: usize, unit: MatrixUnit) -> u64 {
    CkptLayout::new(*cfg, FormatId::Q8).matrix_offset(layer, unit)
}

/// Total on-disk bytes of one matrix-granular unit in an LFQ8 file.
#[deprecated(note = "use CkptLayout::new(cfg, FormatId::Q8).matrix_bytes(unit)")]
pub fn q8_matrix_bytes(cfg: &LlamaConfig, unit: MatrixUnit) -> u64 {
    CkptLayout::new(*cfg, FormatId::Q8).matrix_bytes(unit)
}

// ---------------------------------------------------------------------------
// quantized checkpoints — what the engines load
// ---------------------------------------------------------------------------

/// Read one quantized layer block. Fuses Wq‖Wk‖Wv and W1‖W3 on the fly.
fn read_layer(r: &mut impl Read, cfg: &LlamaConfig, fmt: FormatId) -> Result<QuantLayer> {
    let (d, h, kv, gs) = (cfg.dim, cfg.hidden_dim, cfg.kv_dim(), cfg.gs);
    let att_norm = read_f32s(r, d)?;
    let wq = read_quant(r, d, d, gs, fmt)?;
    let wk = read_quant(r, kv, d, gs, fmt)?;
    let wv = read_quant(r, kv, d, gs, fmt)?;
    let wo = read_quant(r, d, d, gs, fmt)?;
    let ffn_norm = read_f32s(r, d)?;
    let w1 = read_quant(r, h, d, gs, fmt)?;
    let w2 = read_quant(r, d, h, gs, fmt)?;
    let w3 = read_quant(r, h, d, gs, fmt)?;
    Ok(QuantLayer {
        att_norm,
        wqkv: QuantizedTensor::concat_rows(&[&wq, &wk, &wv]),
        wo,
        ffn_norm,
        w13: QuantizedTensor::concat_rows(&[&w1, &w3]),
        w2,
    })
}

/// Load a full quantized checkpoint (any [`FormatId`], identified by
/// its magic) with every layer resident.  Goes through [`CkptSource`],
/// so the exact-length gate applies (truncation and trailing bytes are
/// rejected) and every segment is CRC-verified when the file carries an
/// integrity footer.
pub fn read_ckpt(path: &Path) -> Result<QuantModel> {
    let mut src = CkptSource::open(path)?;
    let cfg = src.cfg;
    let (tok_emb, final_norm, cls) = src.fetch_resident()?;
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for li in 0..cfg.n_layers {
        layers.push(src.fetch_layer(li).with_context(|| format!("layer {li}"))?);
    }
    Ok(QuantModel { cfg, tok_emb, layers, final_norm, cls })
}

/// Load a quantized checkpoint with every layer resident (historical
/// name; reads any quantized format — see [`read_ckpt`]).
pub fn read_q8(path: &Path) -> Result<QuantModel> {
    read_ckpt(path)
}

/// Streaming checkpoint reader: fetches one layer block at a time from
/// disk — the "DDR" the scheduler transfers from.  Keeping only the
/// embeddings, norms and classifier resident mirrors the paper's
/// 111.5 MB buffer strategy instead of the 1.1 GB all-resident layout.
/// Works for every quantized [`FormatId`]; all offsets come from the
/// file's [`CkptLayout`].
pub struct CkptSource {
    file: File,
    /// Model geometry (from the checkpoint header).
    pub cfg: LlamaConfig,
    /// Wire format of the file (from the magic).
    pub fmt: FormatId,
    /// Integrity footer, when the file carries one.  Every fetch is then
    /// CRC-verified against it before the bytes are parsed.
    footer: Option<CkptFooter>,
}

/// Historical name for [`CkptSource`].
#[deprecated(note = "use CkptSource (reads every quantized format)")]
pub type Q8LayerSource = CkptSource;

impl CkptSource {
    pub fn open(path: &Path) -> Result<Self> {
        let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let (cfg, fmt) = read_quant_header(&mut file)?;
        let layout = CkptLayout::new(cfg, fmt);
        // Exact-length gate: the header's geometry fixes the file size
        // (bare content, or content + footer).  Anything else — a
        // truncated copy, trailing garbage, a bit-flipped header that
        // implies a wildly different layout — is rejected here, before
        // any tensor-sized allocation happens.
        let len = file.metadata()?.len();
        let footer = if len == layout.total_bytes() {
            None // pre-footer file: loads, flagged unverified
        } else if len == layout.file_bytes() {
            file.seek(SeekFrom::Start(layout.total_bytes()))?;
            let mut buf = vec![0u8; layout.footer_bytes() as usize];
            file.read_exact(&mut buf).context("reading integrity footer")?;
            Some(CkptFooter::from_bytes(&buf, layout.checksum_count())?)
        } else {
            bail!(
                "checkpoint {path:?} is {len} bytes; header implies {} (bare) or {} (with \
                 integrity footer) — truncated, trailing bytes, or corrupt header",
                layout.total_bytes(),
                layout.file_bytes()
            );
        };
        Ok(CkptSource { file, cfg, fmt, footer })
    }

    /// This file's byte layout.
    pub fn layout(&self) -> CkptLayout {
        CkptLayout::new(self.cfg, self.fmt)
    }

    /// Whether fetches from this source are CRC-verified (the file
    /// carries an integrity footer).  Footer-less files still serve
    /// fetches, unverified.
    pub fn verified(&self) -> bool {
        self.footer.is_some()
    }

    /// Read the concatenation of `segs` into one buffer.
    fn read_segments(&mut self, segs: &[(u64, u64)]) -> Result<Vec<u8>> {
        let total: u64 = segs.iter().map(|&(_, len)| len).sum();
        let mut buf = vec![0u8; total as usize];
        let mut at = 0usize;
        for &(off, len) in segs {
            self.file.seek(SeekFrom::Start(off))?;
            self.file
                .read_exact(&mut buf[at..at + len as usize])
                .context("reading checkpoint segment")?;
            at += len as usize;
        }
        Ok(buf)
    }

    /// Verify footer entry `index` against `bytes` (the concatenated
    /// segments it covers).  A mismatch is *detected corruption*: the
    /// staged read is failed before the bytes are parsed, so garbage
    /// never reaches a kernel.
    fn verify_entry(&self, index: usize, bytes: &[u8]) -> Result<()> {
        if let Some(f) = &self.footer {
            let got = crc::crc32(bytes);
            if got != f.crcs[index] {
                bail!(
                    "checksum mismatch in {} (stored {:08x}, computed {got:08x}) — corrupted \
                     checkpoint",
                    self.layout().checksum_label(index),
                    f.crcs[index]
                );
            }
        }
        Ok(())
    }

    /// Read layer `l`'s block (a real disk read every call — deliberate:
    /// this is the off-chip transfer the async scheduler overlaps),
    /// CRC-verifying every staging unit when the file has a footer.
    pub fn fetch_layer(&mut self, layer: usize) -> Result<QuantLayer> {
        if layer >= self.cfg.n_layers {
            bail!("layer {layer} out of range ({} layers)", self.cfg.n_layers);
        }
        let layout = self.layout();
        let base = layout.layer_offset(layer);
        let block = self.read_segments(&[(base, layout.layer_bytes())])?;
        if self.footer.is_some() {
            for &u in &crate::model::MATRIX_UNITS {
                let unit_bytes: Vec<u8> = layout
                    .matrix_segments(layer, u)
                    .iter()
                    .flat_map(|&(off, len)| {
                        let rel = (off - base) as usize;
                        block[rel..rel + len as usize].iter().copied()
                    })
                    .collect();
                self.verify_entry(layout.checksum_index(layer, u), &unit_bytes)?;
            }
        }
        let cfg = self.cfg;
        let mut r: &[u8] = &block;
        read_layer(&mut r, &cfg, self.fmt)
    }

    /// Read one matrix-granular chunk of layer `layer` — the sub-layer
    /// staging unit of `--stream-granularity matrix`.  Only the chunk's
    /// own byte segments are read (a ~45 MB TinyLlama layer is never
    /// pulled to fetch its ~66 KB norm vectors), CRC-verified as a unit
    /// when the file has a footer, and fused blocks come back exactly as
    /// [`CkptSource::fetch_layer`] fuses them, so matrix-granular
    /// staging is bit-identical to layer-granular.
    pub fn fetch_matrix(&mut self, layer: usize, unit: MatrixUnit) -> Result<LayerChunk> {
        if layer >= self.cfg.n_layers {
            bail!("layer {layer} out of range ({} layers)", self.cfg.n_layers);
        }
        let cfg = self.cfg;
        let fmt = self.fmt;
        let (d, h, kv, gs) = (cfg.dim, cfg.hidden_dim, cfg.kv_dim(), cfg.gs);
        let layout = self.layout();
        let segs = layout.matrix_segments(layer, unit);
        let buf = self.read_segments(&segs)?;
        self.verify_entry(layout.checksum_index(layer, unit), &buf)?;
        // the concatenated segment order matches the parse order exactly
        let mut r: &[u8] = &buf;
        match unit {
            MatrixUnit::Norms => {
                let att_norm = read_f32s(&mut r, d)?;
                let ffn_norm = read_f32s(&mut r, d)?;
                Ok(LayerChunk::Norms { att_norm, ffn_norm })
            }
            MatrixUnit::Qkv => {
                let wq = read_quant(&mut r, d, d, gs, fmt)?;
                let wk = read_quant(&mut r, kv, d, gs, fmt)?;
                let wv = read_quant(&mut r, kv, d, gs, fmt)?;
                Ok(LayerChunk::Mat(QuantizedTensor::concat_rows(&[&wq, &wk, &wv])))
            }
            MatrixUnit::Wo => Ok(LayerChunk::Mat(read_quant(&mut r, d, d, gs, fmt)?)),
            MatrixUnit::W13 => {
                let w1 = read_quant(&mut r, h, d, gs, fmt)?;
                let w3 = read_quant(&mut r, h, d, gs, fmt)?;
                Ok(LayerChunk::Mat(QuantizedTensor::concat_rows(&[&w1, &w3])))
            }
            MatrixUnit::W2 => Ok(LayerChunk::Mat(read_quant(&mut r, d, h, gs, fmt)?)),
        }
    }

    /// Non-layer ("resident") tensors: embeddings, final norm,
    /// classifier — CRC-verified when the file has a footer.
    pub fn fetch_resident(
        &mut self,
    ) -> Result<(QuantizedTensor, Vec<f32>, QuantizedTensor)> {
        let cfg = self.cfg;
        let fmt = self.fmt;
        let layout = self.layout();
        let emb = self.read_segments(&layout.checksum_segments(0))?;
        self.verify_entry(0, &emb)?;
        // entry 0's segment includes the header; the tensor starts after it
        let mut r: &[u8] = &emb[HEADER_BYTES as usize..];
        let tok_emb = read_quant(&mut r, cfg.vocab_size, cfg.dim, cfg.gs, fmt)?;
        let tail_idx = layout.checksum_count() - 1;
        let tail = self.read_segments(&layout.checksum_segments(tail_idx))?;
        self.verify_entry(tail_idx, &tail)?;
        let mut r: &[u8] = &tail;
        let final_norm = read_f32s(&mut r, cfg.dim)?;
        let cls = read_quant(&mut r, cfg.vocab_size, cfg.dim, cfg.gs, fmt)?;
        Ok((tok_emb, final_norm, cls))
    }
}

/// Write a quantized checkpoint in format `fmt` from an (unfused) float
/// model — used by tests, `llamaf synth` and `llamaf import-gguf`.
/// Appends the CRC-32 integrity footer after the content.
pub fn write_ckpt_from_float(path: &Path, fm: &FloatModel, fmt: FormatId) -> Result<()> {
    let cfg = fm.cfg;
    let gs = cfg.gs;
    let mut w = BufWriter::new(File::create(path)?);
    write_header(&mut w, &fmt.magic(), &cfg)?;
    let q = |data: &[f32], rows: usize, cols: usize| {
        QuantizedTensor::from_f32_fmt(data, rows, cols, gs, fmt)
    };
    write_quant(&mut w, &q(&fm.tok_emb, cfg.vocab_size, cfg.dim))?;
    for l in &fm.layers {
        write_f32s(&mut w, &l.att_norm)?;
        write_quant(&mut w, &q(&l.wq, cfg.dim, cfg.dim))?;
        write_quant(&mut w, &q(&l.wk, cfg.kv_dim(), cfg.dim))?;
        write_quant(&mut w, &q(&l.wv, cfg.kv_dim(), cfg.dim))?;
        write_quant(&mut w, &q(&l.wo, cfg.dim, cfg.dim))?;
        write_f32s(&mut w, &l.ffn_norm)?;
        write_quant(&mut w, &q(&l.w1, cfg.hidden_dim, cfg.dim))?;
        write_quant(&mut w, &q(&l.w2, cfg.dim, cfg.hidden_dim))?;
        write_quant(&mut w, &q(&l.w3, cfg.hidden_dim, cfg.dim))?;
    }
    write_f32s(&mut w, &fm.final_norm)?;
    write_quant(&mut w, &q(&fm.cls, cfg.vocab_size, cfg.dim))?;
    w.flush()?;
    drop(w);
    append_footer(path)
}

/// Write an LFQ8 checkpoint from an (unfused) float model by quantizing
/// (the INT8 special case of [`write_ckpt_from_float`]).
pub fn write_q8_from_float(path: &Path, fm: &FloatModel) -> Result<()> {
    write_ckpt_from_float(path, fm, FormatId::Q8)
}

// ---------------------------------------------------------------------------
// LFCK (float) — the W32A32 baseline for Table V
// ---------------------------------------------------------------------------

pub fn read_f32_model(path: &Path) -> Result<FloatModel> {
    let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
    let cfg = read_header(&mut r, MAGIC_F32)?;
    let (d, h, kv) = (cfg.dim, cfg.hidden_dim, cfg.kv_dim());
    let tok_emb = read_f32s(&mut r, cfg.vocab_size * d)?;
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for _ in 0..cfg.n_layers {
        layers.push(FloatLayer {
            att_norm: read_f32s(&mut r, d)?,
            wq: read_f32s(&mut r, d * d)?,
            wk: read_f32s(&mut r, kv * d)?,
            wv: read_f32s(&mut r, kv * d)?,
            wo: read_f32s(&mut r, d * d)?,
            ffn_norm: read_f32s(&mut r, d)?,
            w1: read_f32s(&mut r, h * d)?,
            w2: read_f32s(&mut r, d * h)?,
            w3: read_f32s(&mut r, h * d)?,
        });
    }
    let final_norm = read_f32s(&mut r, d)?;
    let cls = read_f32s(&mut r, cfg.vocab_size * d)?;
    let mut trailing = Vec::new();
    r.read_to_end(&mut trailing)?;
    if !trailing.is_empty() {
        bail!("{} trailing bytes after checkpoint", trailing.len());
    }
    Ok(FloatModel { cfg, tok_emb, layers, final_norm, cls })
}

pub fn write_f32_model(path: &Path, fm: &FloatModel) -> Result<()> {
    let cfg = fm.cfg;
    let mut w = BufWriter::new(File::create(path)?);
    write_header(&mut w, MAGIC_F32, &cfg)?;
    write_f32s(&mut w, &fm.tok_emb)?;
    for l in &fm.layers {
        for t in [&l.att_norm, &l.wq, &l.wk, &l.wv, &l.wo, &l.ffn_norm, &l.w1, &l.w2, &l.w3] {
            write_f32s(&mut w, t)?;
        }
    }
    write_f32s(&mut w, &fm.final_norm)?;
    write_f32s(&mut w, &fm.cls)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LlamaConfig;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    #[test]
    fn f32_roundtrip() {
        let fm = FloatModel::random(tiny_cfg(), 1);
        let dir = std::env::temp_dir().join("llamaf_test_f32.lfck");
        write_f32_model(&dir, &fm).unwrap();
        let fm2 = read_f32_model(&dir).unwrap();
        assert_eq!(fm2.cfg, fm.cfg);
        assert_eq!(fm2.tok_emb, fm.tok_emb);
        assert_eq!(fm2.layers[1].w2, fm.layers[1].w2);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn q8_roundtrip_matches_in_memory_quantization() {
        let fm = FloatModel::random(tiny_cfg(), 2);
        let path = std::env::temp_dir().join("llamaf_test_q8.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        let qm_file = read_ckpt(&path).unwrap();
        let qm_mem = QuantModel::from_float(&fm);
        assert_eq!(qm_file.tok_emb, qm_mem.tok_emb);
        for (a, b) in qm_file.layers.iter().zip(&qm_mem.layers) {
            assert_eq!(a.wqkv, b.wqkv);
            assert_eq!(a.wo, b.wo);
            assert_eq!(a.w13, b.w13);
            assert_eq!(a.w2, b.w2);
            assert_eq!(a.att_norm, b.att_norm);
        }
        assert_eq!(qm_file.cls, qm_mem.cls);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn every_format_roundtrips_and_pins_file_size() {
        // write -> read round trip per format, against the in-memory
        // quantizer, plus CkptLayout::file_bytes pinning the real file
        // length (content + integrity footer — the byte-accounting
        // contract the streamer bills by)
        let fm = FloatModel::random(tiny_cfg(), 20);
        for fmt in FormatId::ALL {
            let path =
                std::env::temp_dir().join(format!("llamaf_test_rt_{}.lfq", fmt.name()));
            write_ckpt_from_float(&path, &fm, fmt).unwrap();
            let layout = CkptLayout::new(fm.cfg, fmt);
            assert_eq!(
                std::fs::metadata(&path).unwrap().len(),
                layout.file_bytes(),
                "{fmt}: file length != CkptLayout::file_bytes"
            );
            let (cfg, peeked) = peek_config(&path).unwrap();
            assert_eq!(cfg, fm.cfg);
            assert_eq!(peeked, Some(fmt));
            let qm_file = read_ckpt(&path).unwrap();
            let qm_mem = QuantModel::from_float_fmt(&fm, fmt);
            assert_eq!(qm_file.tok_emb, qm_mem.tok_emb, "{fmt}");
            for (a, b) in qm_file.layers.iter().zip(&qm_mem.layers) {
                assert_eq!(a.wqkv, b.wqkv, "{fmt}");
                assert_eq!(a.w13, b.w13, "{fmt}");
                assert_eq!(a.w2, b.w2, "{fmt}");
            }
            assert_eq!(qm_file.cls, qm_mem.cls, "{fmt}");
            assert_eq!(qm_file.fmt(), fmt);
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn layer_source_matches_full_read_every_format() {
        let fm = FloatModel::random(tiny_cfg(), 3);
        for fmt in FormatId::ALL {
            let path =
                std::env::temp_dir().join(format!("llamaf_test_stream_{}.lfq", fmt.name()));
            write_ckpt_from_float(&path, &fm, fmt).unwrap();
            let qm = read_ckpt(&path).unwrap();
            let mut src = CkptSource::open(&path).unwrap();
            assert_eq!(src.fmt, fmt);
            for li in 0..qm.cfg.n_layers {
                let layer = src.fetch_layer(li).unwrap();
                assert_eq!(layer.wqkv, qm.layers[li].wqkv, "{fmt} layer {li}");
                assert_eq!(layer.w2, qm.layers[li].w2, "{fmt} layer {li}");
            }
            let (emb, norm, cls) = src.fetch_resident().unwrap();
            assert_eq!(emb, qm.tok_emb);
            assert_eq!(norm, qm.final_norm);
            assert_eq!(cls, qm.cls);
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let path = std::env::temp_dir().join("llamaf_test_badmagic.lfq8");
        std::fs::write(&path, b"XXXX0000000000000000000000000000000000000000").unwrap();
        assert!(read_ckpt(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncated_rejected() {
        let fm = FloatModel::random(tiny_cfg(), 4);
        let path = std::env::temp_dir().join("llamaf_test_trunc.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..data.len() - 10]).unwrap();
        assert!(read_ckpt(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn trailing_bytes_rejected() {
        let fm = FloatModel::random(tiny_cfg(), 5);
        let path = std::env::temp_dir().join("llamaf_test_trail.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        let mut data = std::fs::read(&path).unwrap();
        data.extend_from_slice(&[0u8; 13]);
        std::fs::write(&path, &data).unwrap();
        assert!(read_ckpt(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn layer_offsets_consistent() {
        let cfg = tiny_cfg();
        let fm = FloatModel::random(cfg, 6);
        let path = std::env::temp_dir().join("llamaf_test_off.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        let file_len = std::fs::metadata(&path).unwrap().len();
        let layout = CkptLayout::new(cfg, FormatId::Q8);
        let expected = layout.layer_offset(cfg.n_layers)
            + 4 * cfg.dim as u64
            + layout.tensor_bytes(cfg.vocab_size, cfg.dim);
        assert_eq!(file_len, expected + layout.footer_bytes());
        assert_eq!(file_len, layout.file_bytes());
        std::fs::remove_file(path).ok();
    }

    /// Serialize a quantized tensor exactly as the LFQ8 writer does
    /// (int8 data then f32 LE scales) — the oracle for offset pinning.
    fn q8_bytes(t: &QuantizedTensor) -> Vec<u8> {
        let mut out: Vec<u8> = t.q.iter().map(|&v| v as u8).collect();
        for &s in &t.s {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out
    }

    fn f32_bytes(v: &[f32]) -> Vec<u8> {
        v.iter().flat_map(|x| x.to_le_bytes()).collect()
    }

    #[test]
    fn matrix_segments_tile_every_layer_block_every_format() {
        let cfg = tiny_cfg();
        for fmt in FormatId::ALL {
            let layout = CkptLayout::new(cfg, fmt);
            for layer in 0..cfg.n_layers {
                let mut segs: Vec<(u64, u64)> = crate::model::MATRIX_UNITS
                    .iter()
                    .flat_map(|&u| layout.matrix_segments(layer, u))
                    .collect();
                segs.sort_unstable();
                let base = layout.layer_offset(layer);
                let mut cursor = base;
                for (off, len) in segs {
                    assert_eq!(off, cursor, "{fmt}: gap or overlap at offset {off}");
                    cursor += len;
                }
                assert_eq!(
                    cursor,
                    base + layout.layer_bytes(),
                    "{fmt}: segments must cover the block"
                );
            }
            let total: u64 = crate::model::MATRIX_UNITS
                .iter()
                .map(|&u| layout.matrix_bytes(u))
                .sum();
            assert_eq!(total, layout.layer_bytes());
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_q8_wrappers_agree_with_layout() {
        // the one-PR compatibility contract: every q8_* free function
        // returns exactly what CkptLayout(Q8) computes
        let cfg = tiny_cfg();
        let layout = CkptLayout::new(cfg, FormatId::Q8);
        assert_eq!(q8_layer_bytes(&cfg), layout.layer_bytes());
        for layer in 0..cfg.n_layers {
            assert_eq!(q8_layer_offset(&cfg, layer), layout.layer_offset(layer));
            for &u in &crate::model::MATRIX_UNITS {
                assert_eq!(q8_matrix_segments(&cfg, layer, u), layout.matrix_segments(layer, u));
                assert_eq!(q8_matrix_offset(&cfg, layer, u), layout.matrix_offset(layer, u));
            }
        }
        for &u in &crate::model::MATRIX_UNITS {
            assert_eq!(q8_matrix_bytes(&cfg, u), layout.matrix_bytes(u));
        }
    }

    #[test]
    fn layer_and_matrix_offsets_pin_written_byte_layout() {
        // The format contract: CkptLayout's offsets must locate the EXACT
        // bytes write_ckpt_from_float puts on disk for the historical Q8
        // encoding — format drift fails here, loudly.
        use crate::model::MatrixUnit;
        let cfg = tiny_cfg();
        let fm = FloatModel::random(cfg, 8);
        let path = std::env::temp_dir().join("llamaf_test_layout.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        let raw = std::fs::read(&path).unwrap();
        let gs = cfg.gs;
        let layout = CkptLayout::new(cfg, FormatId::Q8);
        let at = |off: u64, len: usize| &raw[off as usize..off as usize + len];
        assert_eq!(
            layout.layer_offset(1) - layout.layer_offset(0),
            layout.layer_bytes(),
            "consecutive layer blocks must be exactly layer_bytes apart"
        );
        for (li, fl) in fm.layers.iter().enumerate() {
            // layer block starts with the raw f32 att_norm
            let base = layout.layer_offset(li);
            assert_eq!(at(base, 4 * cfg.dim), &f32_bytes(&fl.att_norm)[..], "layer {li} base");
            // Norms unit: att_norm at segment 0, ffn_norm at segment 1
            let segs = layout.matrix_segments(li, MatrixUnit::Norms);
            assert_eq!(layout.matrix_offset(li, MatrixUnit::Norms), base);
            assert_eq!(at(segs[1].0, segs[1].1 as usize), &f32_bytes(&fl.ffn_norm)[..]);
            // Qkv unit: wq then wk then wv, quantized exactly like the writer
            let wq = QuantizedTensor::from_f32(&fl.wq, cfg.dim, cfg.dim, gs);
            let off = layout.matrix_offset(li, MatrixUnit::Qkv);
            let wq_bytes = q8_bytes(&wq);
            assert_eq!(at(off, wq_bytes.len()), &wq_bytes[..], "layer {li} wq");
            // W2 unit is one contiguous tensor
            let w2 = QuantizedTensor::from_f32(&fl.w2, cfg.dim, cfg.hidden_dim, gs);
            let off = layout.matrix_offset(li, MatrixUnit::W2);
            let w2_bytes = q8_bytes(&w2);
            assert_eq!(at(off, w2_bytes.len()), &w2_bytes[..], "layer {li} w2");
            // W13 unit: w1 at segment 0, w3 at segment 1 (w2 sits between)
            let segs = layout.matrix_segments(li, MatrixUnit::W13);
            let w1 = QuantizedTensor::from_f32(&fl.w1, cfg.hidden_dim, cfg.dim, gs);
            let w3 = QuantizedTensor::from_f32(&fl.w3, cfg.hidden_dim, cfg.dim, gs);
            assert_eq!(at(segs[0].0, segs[0].1 as usize), &q8_bytes(&w1)[..], "layer {li} w1");
            assert_eq!(at(segs[1].0, segs[1].1 as usize), &q8_bytes(&w3)[..], "layer {li} w3");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn fetch_matrix_matches_fused_layer_read_every_format() {
        use crate::model::{LayerChunk, MATRIX_UNITS};
        let fm = FloatModel::random(tiny_cfg(), 9);
        for fmt in FormatId::ALL {
            let path =
                std::env::temp_dir().join(format!("llamaf_test_fetchmat_{}.lfq", fmt.name()));
            write_ckpt_from_float(&path, &fm, fmt).unwrap();
            let qm = read_ckpt(&path).unwrap();
            let mut src = CkptSource::open(&path).unwrap();
            for (li, lay) in qm.layers.iter().enumerate() {
                for &u in &MATRIX_UNITS {
                    match (src.fetch_matrix(li, u).unwrap(), u) {
                        (
                            LayerChunk::Norms { att_norm, ffn_norm },
                            crate::model::MatrixUnit::Norms,
                        ) => {
                            assert_eq!(att_norm, lay.att_norm);
                            assert_eq!(ffn_norm, lay.ffn_norm);
                        }
                        (LayerChunk::Mat(t), crate::model::MatrixUnit::Qkv) => {
                            assert_eq!(t, lay.wqkv, "{fmt}")
                        }
                        (LayerChunk::Mat(t), crate::model::MatrixUnit::Wo) => {
                            assert_eq!(t, lay.wo, "{fmt}")
                        }
                        (LayerChunk::Mat(t), crate::model::MatrixUnit::W13) => {
                            assert_eq!(t, lay.w13, "{fmt}")
                        }
                        (LayerChunk::Mat(t), crate::model::MatrixUnit::W2) => {
                            assert_eq!(t, lay.w2, "{fmt}")
                        }
                        _ => panic!("chunk shape does not match requested unit {u:?}"),
                    }
                }
            }
            assert!(src.fetch_matrix(99, crate::model::MatrixUnit::Qkv).is_err());
            std::fs::remove_file(path).ok();
        }
    }

    #[test]
    fn out_of_range_layer_rejected() {
        let fm = FloatModel::random(tiny_cfg(), 7);
        let path = std::env::temp_dir().join("llamaf_test_oor.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        let mut src = CkptSource::open(&path).unwrap();
        assert!(src.fetch_layer(99).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn sub_int8_formats_really_shrink_the_file() {
        let fm = FloatModel::random(tiny_cfg(), 21);
        let mut sizes = std::collections::HashMap::new();
        for fmt in FormatId::ALL {
            let path =
                std::env::temp_dir().join(format!("llamaf_test_size_{}.lfq", fmt.name()));
            write_ckpt_from_float(&path, &fm, fmt).unwrap();
            sizes.insert(fmt, std::fs::metadata(&path).unwrap().len() as f64);
            std::fs::remove_file(path).ok();
        }
        let ratio = sizes[&FormatId::Q40] / sizes[&FormatId::Q8];
        assert!(ratio <= 0.62, "q4_0 file should be ~half of q8 (got {ratio:.3})");
        assert!(sizes[&FormatId::Q50] < sizes[&FormatId::Q8]);
        assert!(sizes[&FormatId::Q40] < sizes[&FormatId::Q50]);
    }

    // ------------------------------------------------------------------
    // Integrity footer
    // ------------------------------------------------------------------

    #[test]
    fn footer_written_verified_and_optional() {
        let fm = FloatModel::random(tiny_cfg(), 30);
        let path = std::env::temp_dir().join("llamaf_test_footer.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        // freshly written files verify end to end
        let layout = CkptLayout::new(fm.cfg, FormatId::Q8);
        assert_eq!(
            verify_ckpt(&path).unwrap(),
            VerifyOutcome::Ok { segments: layout.checksum_count() }
        );
        assert!(CkptSource::open(&path).unwrap().verified());
        // stripping the footer leaves a legal pre-footer file: it loads
        // (flagged unverified) and the offline pass reports NoFooter
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..layout.total_bytes() as usize]).unwrap();
        assert!(!CkptSource::open(&path).unwrap().verified());
        assert_eq!(verify_ckpt(&path).unwrap(), VerifyOutcome::NoFooter);
        let qm = read_ckpt(&path).unwrap();
        assert_eq!(qm.cfg, fm.cfg);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_segment_rejected_at_staging_time() {
        use crate::model::MatrixUnit;
        let fm = FloatModel::random(tiny_cfg(), 31);
        let path = std::env::temp_dir().join("llamaf_test_corrupt.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        let layout = CkptLayout::new(fm.cfg, FormatId::Q8);
        // flip one payload byte inside layer 1's W2 — a flip that parses
        // fine as int8, so only the CRC can catch it
        let mut data = std::fs::read(&path).unwrap();
        let off = layout.matrix_segments(1, MatrixUnit::W2)[0].0 as usize + 7;
        data[off] ^= 0xFF;
        std::fs::write(&path, &data).unwrap();
        // the footer itself is intact, so the file opens...
        let mut src = CkptSource::open(&path).unwrap();
        assert!(src.verified());
        // ...clean segments stage fine...
        src.fetch_layer(0).unwrap();
        src.fetch_matrix(1, MatrixUnit::Qkv).unwrap();
        src.fetch_resident().unwrap();
        // ...and the corrupt unit is rejected BEFORE parsing, at both
        // granularities
        let e = src.fetch_matrix(1, MatrixUnit::W2).unwrap_err().to_string();
        assert!(e.contains("checksum mismatch in layer 1 (w2)"), "{e}");
        let e = src.fetch_layer(1).unwrap_err().to_string();
        assert!(e.contains("checksum"), "{e}");
        // full loads refuse too, and the offline pass names the segment
        assert!(read_ckpt(&path).is_err());
        let e = verify_ckpt(&path).unwrap_err().to_string();
        assert!(e.contains("layer 1 (w2)"), "{e}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_footer_is_detected_not_trusted() {
        let fm = FloatModel::random(tiny_cfg(), 32);
        let path = std::env::temp_dir().join("llamaf_test_badfooter.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        let layout = CkptLayout::new(fm.cfg, FormatId::Q8);
        let mut data = std::fs::read(&path).unwrap();
        // flip a stored CRC inside the footer (past magic/version/count)
        let off = layout.total_bytes() as usize + 13;
        data[off] ^= 0x55;
        std::fs::write(&path, &data).unwrap();
        let e = CkptSource::open(&path).unwrap_err().to_string();
        assert!(e.contains("footer"), "{e}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_resident_tensors_rejected() {
        let fm = FloatModel::random(tiny_cfg(), 33);
        let path = std::env::temp_dir().join("llamaf_test_badresident.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        let mut data = std::fs::read(&path).unwrap();
        data[HEADER_BYTES as usize + 3] ^= 0x01; // inside tok_emb
        std::fs::write(&path, &data).unwrap();
        let mut src = CkptSource::open(&path).unwrap();
        let e = src.fetch_resident().unwrap_err().to_string();
        assert!(e.contains("checksum mismatch in header+tok_emb"), "{e}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn footer_survives_every_format() {
        let fm = FloatModel::random(tiny_cfg(), 34);
        for fmt in FormatId::ALL {
            let path =
                std::env::temp_dir().join(format!("llamaf_test_vfy_{}.lfq", fmt.name()));
            write_ckpt_from_float(&path, &fm, fmt).unwrap();
            let layout = CkptLayout::new(fm.cfg, fmt);
            assert_eq!(
                verify_ckpt(&path).unwrap(),
                VerifyOutcome::Ok { segments: layout.checksum_count() },
                "{fmt}"
            );
            std::fs::remove_file(path).ok();
        }
    }

    /// Mutation corpus for the LFQ* reader.  With the integrity footer
    /// in place the guarantee is stronger than "no panic": EVERY
    /// mutation must be rejected — any byte flip lands in the header
    /// (magic/geometry gate), the content (segment CRC), or the footer
    /// (footer self-CRC), and any truncation or extension trips the
    /// exact-length gate at open.
    #[test]
    fn mutation_corpus_lfq_reader_rejects_everything() {
        let fm = FloatModel::random(tiny_cfg(), 35);
        let path = std::env::temp_dir().join("llamaf_test_lfq_mutate.lfq8");
        write_q8_from_float(&path, &fm).unwrap();
        let clean = std::fs::read(&path).unwrap();
        let mut rng = crate::util::Rng::new(0xFA02);
        for i in 0..200 {
            let mut bad = clean.clone();
            match i % 4 {
                0 => {
                    let pos = rng.below(bad.len() as u64) as usize;
                    bad[pos] ^= rng.below(255) as u8 + 1;
                }
                1 => {
                    // any cut except exactly stripping the footer (which
                    // is a legal pre-footer file, not a corruption)
                    let legal = CkptLayout::new(fm.cfg, FormatId::Q8).total_bytes();
                    let mut cut = rng.below(bad.len() as u64);
                    if cut == legal {
                        cut -= 1;
                    }
                    bad.truncate(cut as usize);
                }
                2 => bad.extend_from_slice(&[0u8; 17]),
                _ => {
                    // burst inside the header: geometry fields — the
                    // length gate must reject count-sized implications
                    // before any allocation happens
                    for _ in 0..4 {
                        let pos = rng.below(HEADER_BYTES) as usize;
                        bad[pos] ^= rng.below(255) as u8 + 1;
                    }
                }
            }
            std::fs::write(&path, &bad).unwrap();
            assert!(read_ckpt(&path).is_err(), "mutation {i} was accepted");
        }
        std::fs::remove_file(path).ok();
    }
}
