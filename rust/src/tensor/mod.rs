//! Minimal f32 tensor math for the PS-side compute.
//!
//! The paper keeps RMSNorm, RoPE, multi-head attention, SwiGLU and sampling
//! on the PS (§III-B); these are their building blocks.  Everything is
//! flat-`Vec<f32>` based — batch size is 1 throughout (the paper argues
//! real-time embedded inference requires it).

/// Epsilon used by RMSNorm (matches python/compile/model.py RMS_EPS).
pub const RMS_EPS: f32 = 1e-5;

/// RoPE base frequency (matches ROPE_THETA).
pub const ROPE_THETA: f32 = 10000.0;

/// out = x * w / sqrt(mean(x^2) + eps)   (RMSNorm, Zhang & Sennrich 2019)
pub fn rmsnorm(out: &mut [f32], x: &[f32], w: &[f32]) {
    debug_assert_eq!(x.len(), w.len());
    debug_assert_eq!(x.len(), out.len());
    let mut ss = 0.0f32;
    for &v in x {
        ss += v * v;
    }
    ss = ss / x.len() as f32 + RMS_EPS;
    let inv = 1.0 / ss.sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * w[i];
    }
}

/// In-place numerically-stable softmax over `x[..n]`.
pub fn softmax(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let max = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// SwiGLU gate: h1 <- silu(h1) * h3, with silu(x) = x * sigmoid(x).
pub fn swiglu(h1: &mut [f32], h3: &[f32]) {
    debug_assert_eq!(h1.len(), h3.len());
    for i in 0..h1.len() {
        let x = h1[i];
        h1[i] = x / (1.0 + (-x).exp()) * h3[i];
    }
}

/// Rotary position embedding, llama2.c interleaved-pair convention.
///
/// `x` is a concatenation of heads, each `head_dim` wide; pair (2i, 2i+1)
/// of every head is rotated by pos * theta^(-2i/head_dim).
pub fn rope(x: &mut [f32], pos: usize, head_dim: usize) {
    debug_assert_eq!(x.len() % head_dim, 0);
    let half = head_dim / 2;
    for h in 0..x.len() / head_dim {
        let base = h * head_dim;
        for i in 0..half {
            let freq = ROPE_THETA.powf(-(2.0 * i as f32) / head_dim as f32);
            let ang = pos as f32 * freq;
            let (sin, cos) = ang.sin_cos();
            let a = x[base + 2 * i];
            let b = x[base + 2 * i + 1];
            x[base + 2 * i] = a * cos - b * sin;
            x[base + 2 * i + 1] = a * sin + b * cos;
        }
    }
}

/// out += x  (residual connection)
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    for i in 0..out.len() {
        out[i] += x[i];
    }
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Index of the maximum element (greedy sampling).
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Top-p (nucleus) sampling from raw logits with temperature.
/// `coin` is a uniform [0,1) random number supplied by the caller.
pub fn sample_top_p(logits: &[f32], top_p: f32, temperature: f32, coin: f32) -> usize {
    assert!(temperature > 0.0);
    let mut probs: Vec<f32> = logits.iter().map(|&l| l / temperature).collect();
    softmax(&mut probs);
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
    let mut cum = 0.0f32;
    let mut cutoff = idx.len();
    for (rank, &i) in idx.iter().enumerate() {
        cum += probs[i];
        if cum >= top_p {
            cutoff = rank + 1;
            break;
        }
    }
    let renorm: f32 = idx[..cutoff].iter().map(|&i| probs[i]).sum();
    let target = coin * renorm;
    let mut acc = 0.0f32;
    for &i in &idx[..cutoff] {
        acc += probs[i];
        if acc >= target {
            return i;
        }
    }
    idx[cutoff - 1]
}

/// Float matvec out = W x, for float-vs-quantized comparisons.
pub fn matvec_f32(out: &mut [f32], w: &[f32], x: &[f32]) {
    let n = x.len();
    debug_assert_eq!(w.len(), out.len() * n);
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot(&w[i * n..(i + 1) * n], x);
    }
}

/// log-sum-exp over logits (PPL evaluation).
pub fn log_sum_exp(x: &[f32]) -> f32 {
    let max = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let s: f32 = x.iter().map(|&v| (v - max).exp()).sum();
    max + s.ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn rmsnorm_unit_rms() {
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(256, 2.0);
        let w = vec![1.0f32; 256];
        let mut out = vec![0.0; 256];
        rmsnorm(&mut out, &x, &w);
        let rms: f32 = out.iter().map(|v| v * v).sum::<f32>() / 256.0;
        assert!((rms - 1.0).abs() < 1e-3, "rms {rms}");
    }

    #[test]
    fn rmsnorm_scale_invariant() {
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(64, 1.0);
        let x_scaled: Vec<f32> = x.iter().map(|v| v * 1000.0).collect();
        let w = rng.normal_vec(64, 1.0);
        let mut a = vec![0.0; 64];
        let mut b = vec![0.0; 64];
        rmsnorm(&mut a, &x, &w);
        rmsnorm(&mut b, &x_scaled, &w);
        for i in 0..64 {
            assert!((a[i] - b[i]).abs() < 1e-3 * (1.0 + a[i].abs()));
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        softmax(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut x = vec![1000.0f32, 1000.0, 999.0];
        softmax(&mut x);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn rope_preserves_norm_and_identity_at_zero() {
        let mut rng = Rng::new(3);
        let head_dim = 64;
        let orig = rng.normal_vec(2 * head_dim, 1.0);
        let mut x = orig.clone();
        rope(&mut x, 0, head_dim);
        for i in 0..x.len() {
            assert!((x[i] - orig[i]).abs() < 1e-6);
        }
        let mut y = orig.clone();
        rope(&mut y, 17, head_dim);
        let n0: f32 = orig.iter().map(|v| v * v).sum::<f32>().sqrt();
        let n1: f32 = y.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!((n0 - n1).abs() < 1e-3);
    }

    #[test]
    fn rope_is_additive_in_position() {
        // rotating by pos a then re-deriving from scratch at pos a must
        // equal composing rotations: R(a+b) v == R(b) R(a) v
        let mut rng = Rng::new(4);
        let head_dim = 8;
        let v = rng.normal_vec(head_dim, 1.0);
        let mut direct = v.clone();
        rope(&mut direct, 5, head_dim);
        // R(2) then R(3) — angles add per pair
        // (only true because each pair is a pure rotation by pos*freq)
        let mut composed = v.clone();
        rope(&mut composed, 2, head_dim);
        rope(&mut composed, 3, head_dim);
        for i in 0..head_dim {
            assert!(
                (direct[i] - composed[i]).abs() < 1e-4,
                "i={i} {} vs {}",
                direct[i],
                composed[i]
            );
        }
    }

    #[test]
    fn swiglu_matches_definition() {
        let mut h1 = vec![0.5f32, -1.0, 2.0];
        let h3 = vec![2.0f32, 3.0, 0.5];
        let expect: Vec<f32> = h1
            .iter()
            .zip(&h3)
            .map(|(&a, &b)| a / (1.0 + (-a).exp()) * b)
            .collect();
        swiglu(&mut h1, &h3);
        for i in 0..3 {
            assert!((h1[i] - expect[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn argmax_finds_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-5.0, -2.0, -9.0]), 1);
        assert_eq!(argmax(&[7.0]), 0);
    }

    #[test]
    fn top_p_greedy_limit() {
        // tiny top_p selects the argmax deterministically
        let logits = vec![0.0f32, 5.0, 1.0, -2.0];
        for coin in [0.0, 0.5, 0.99] {
            assert_eq!(sample_top_p(&logits, 1e-6, 1.0, coin), 1);
        }
    }

    #[test]
    fn top_p_full_distribution_valid_index() {
        let mut rng = Rng::new(6);
        let logits = rng.normal_vec(32, 1.0);
        for _ in 0..100 {
            let idx = sample_top_p(&logits, 0.9, 0.8, rng.next_f32());
            assert!(idx < 32);
        }
    }

    #[test]
    fn log_sum_exp_stable() {
        let x = vec![1000.0f32, 1000.0];
        let l = log_sum_exp(&x);
        assert!((l - (1000.0 + (2.0f32).ln())).abs() < 1e-3);
    }

    #[test]
    fn matvec_matches_manual() {
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let x = vec![1.0, 0.5, -1.0];
        let mut out = vec![0.0; 2];
        matvec_f32(&mut out, &w, &x);
        assert_eq!(out, vec![1.0 + 1.0 - 3.0, 4.0 + 2.5 - 6.0]);
    }
}
