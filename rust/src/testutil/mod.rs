//! Mini property-testing framework (proptest is unavailable offline).
//!
//! `forall` runs a property across `iters` deterministic seeds; on failure
//! it panics with the exact seed so the case can be replayed:
//!
//! ```rust,no_run
//! use llamaf::testutil::forall;
//! forall("quant roundtrip", 64, |rng| {
//!     let x = rng.normal_vec(256, 1.0);
//!     // ... return true if the property holds
//!     !x.is_empty()
//! });
//! ```

use crate::util::Rng;

/// Multiplier for randomized-suite case counts, read from the
/// `LLAMAF_TEST_REPEATS` environment variable (default 1, the fixed-seed
/// CI configuration).  Setting it to N sweeps N× the seeds — the opt-in
/// soak knob for multi-seed runs (`LLAMAF_TEST_REPEATS=8 cargo test`).
/// Unparseable or zero values fall back to 1 rather than silently
/// skipping cases.
pub fn repeats() -> u64 {
    std::env::var("LLAMAF_TEST_REPEATS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Run `prop` for `iters * repeats()` seeded cases; panic with the
/// failing seed.  Seeds are deterministic and independent of the repeat
/// multiplier: case `i` always replays identically.
pub fn forall<F>(name: &str, iters: u64, prop: F)
where
    F: Fn(&mut Rng) -> bool,
{
    for seed in 0..iters.saturating_mul(repeats()) {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        if !prop(&mut rng) {
            panic!(
                "property '{name}' failed at seed index {seed} \
                 (replay: forall_one(\"{name}\", {seed}, prop))"
            );
        }
    }
}

/// Replay a single seed index from a `forall` failure.
pub fn forall_one<F>(name: &str, seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> bool,
{
    let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
    assert!(prop(&mut rng), "property '{name}' failed at seed index {seed}");
}

/// Relative-or-absolute closeness for float comparisons in properties.
pub fn close(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// All-elements closeness.
pub fn all_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(&x, &y)| close(x, y, rtol, atol))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial() {
        forall("trivial", 16, |rng| rng.next_f64() < 1.0);
    }

    #[test]
    #[should_panic(expected = "seed index")]
    fn forall_reports_seed() {
        forall("always-false", 4, |_| false);
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-6, 0.0));
        assert!(!close(1.0, 1.1, 1e-6, 1e-6));
        assert!(close(0.0, 1e-9, 0.0, 1e-8));
    }

    #[test]
    fn all_close_length_mismatch_fails() {
        assert!(!all_close(&[1.0], &[1.0, 2.0], 1e-6, 1e-6));
    }
}
