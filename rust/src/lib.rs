//! # LlamaF — Llama2-architecture accelerator reproduction
//!
//! Reproduction of *LlamaF: An Efficient Llama2 Architecture Accelerator on
//! Embedded FPGAs* (Xu, Li, Ji — CS.AR 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: the ZCU102 *processing
//!   system* (PS) side of the paper. Transformer control loop
//!   (Algorithm 2), KV cache, RMSNorm/RoPE/attention/SwiGLU, sampling,
//!   weight streaming with sync/async task-level scheduling (Fig. 2), and
//!   the experiment/bench harness for every paper table.
//! * **Layer 2/1 (python, build-time only)** — the JAX model and the Pallas
//!   GQMV kernel, AOT-lowered to HLO text once by `make artifacts`.
//! * **Runtime bridge** — [`runtime`] executes the group-wise quantized
//!   matrix-vector multiply (GQMV) from the decode hot path: the functional
//!   stand-in for the FPGA *programmable logic* (PL).  With `--features
//!   pjrt` it loads `artifacts/*.hlo.txt` through the PJRT C API (`xla`
//!   bindings); by default a bit-exact host simulator serves the same
//!   contract so everything builds and tests offline.
//!
//! On top of the single-stream engine sits a concurrent serving layer
//! ([`server`]): protocol workers share one `Arc`'d weight copy and
//! submit every request to a step-synchronous
//! [`engine::batch::BatchScheduler`], which folds all active sessions
//! into ONE batched pass per decode step — each layer's weights are
//! staged once per step instead of once per session-token, attacking the
//! paper's DDR-bandwidth bound at serving scale.  Per-client KV state
//! lives in a bounded LRU [`engine::session::SessionPool`], and greedy
//! outputs stay byte-identical to batch-1 serving.
//!
//! `docs/ARCHITECTURE.md` maps every module to its paper section;
//! `docs/PROTOCOL.md` specifies the TCP wire protocol.
//!
//! The FPGA itself is additionally modelled by [`fpga`]: a
//! cycle-approximate simulator of the paper's three-stage HLS dataflow
//! pipeline plus AXI bandwidth, resource (Table III) and power models, so
//! the paper-scale numbers (4.696 GOPS, 14.3–15.8× speedup, 6.1× energy
//! efficiency) can be regenerated on this testbed.
//!
//! Quickstart: see `examples/quickstart.rs`, or:
//!
//! ```bash
//! make artifacts && cargo run --release -- generate \
//!     --ckpt artifacts/nano_q8.lfq8 --prompt "the engineer builds" --steps 48
//! ```

pub mod bench;
pub mod ckpt;
pub mod cli;
// The serving-path modules gate `missing_docs`: every public item must be
// documented, enforced by the CI `cargo doc` job (RUSTDOCFLAGS=-D warnings).
#[warn(missing_docs)]
pub mod engine;
pub mod exp;
pub mod fpga;
#[warn(missing_docs)]
pub mod metrics;
pub mod model;
pub mod ps;
pub mod quant;
pub mod runtime;
#[warn(missing_docs)]
pub mod sched;
#[warn(missing_docs)]
pub mod server;
pub mod tensor;
pub mod testutil;
pub mod tokenizer;
#[warn(missing_docs)]
pub mod trace;
pub mod util;

/// Group size used throughout the paper (GS=256); checkpoints carry their
/// own GS in the header, this is only the default.
pub const DEFAULT_GS: usize = 256;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";
