//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + fixed-duration adaptive iteration, reporting mean / p50 / p99
//! and derived throughput.  Used by every `rust/benches/*.rs` target.
//!
//! Two CI-facing facilities live here too:
//!
//! * [`smoke`] — `BENCH_SMOKE=1` puts every harness-driven bench into a
//!   one-quick-iteration mode so the CI `bench-smoke` job can compile and
//!   run the whole `rust/benches/` suite in seconds (drift caught at PR
//!   time, not at measurement time).
//! * [`Report`] — each bench target records its headline numbers and
//!   writes one JSON file (`BENCH_JSON_DIR`, default `bench-json/`); CI
//!   uploads the directory as a workflow artifact.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::stats::percentile;

/// True when `BENCH_SMOKE=1` is set: benches run one quick iteration per
/// case (the CI smoke mode) instead of their full measurement budget.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            crate::util::timer::fmt_duration(self.mean_s),
            crate::util::timer::fmt_duration(self.p50_s),
            crate::util::timer::fmt_duration(self.p99_s),
        )
    }
}

/// Benchmark runner with warmup and a wall-clock budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget_s: f64,
}

impl Default for Bench {
    /// Full measurement budget — or the smoke settings when
    /// `BENCH_SMOKE=1`, so CI never pays for statistics it discards.
    fn default() -> Self {
        if smoke() {
            return Bench { warmup_iters: 0, min_iters: 1, max_iters: 1, budget_s: 0.0 };
        }
        Bench { warmup_iters: 3, min_iters: 10, max_iters: 10_000, budget_s: 2.0 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        if smoke() {
            return Bench::default();
        }
        Bench { warmup_iters: 1, min_iters: 3, max_iters: 100, budget_s: 0.5 }
    }

    /// Run `f` repeatedly; returns timing stats.  `f` should perform one
    /// complete unit of work per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.budget_s && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples_to_result(name, samples)
    }
}

fn samples_to_result(name: &str, mut samples: Vec<f64>) -> BenchResult {
    let iters = samples.len();
    let mean = samples.iter().sum::<f64>() / iters as f64;
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: percentile(&samples, 50.0),
        p99_s: percentile(&samples, 99.0),
        min_s: samples[0],
    }
}

/// Print a section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable bench report.  Each bench target records its headline
/// numbers via [`Report::case`] and writes one JSON file at exit; the CI
/// `bench-smoke` job uploads the directory as a workflow artifact so
/// bench output (and any drift in it) is inspectable per PR.
pub struct Report {
    name: String,
    started: Instant,
    cases: Vec<(String, f64, String)>,
}

impl Report {
    /// Start a report for the bench target `name` (used as the file stem).
    pub fn new(name: &str) -> Self {
        Report { name: name.to_string(), started: Instant::now(), cases: Vec::new() }
    }

    /// Record one headline number (`value` in `unit`) under `case`.
    pub fn case(&mut self, case: &str, value: f64, unit: &str) {
        self.cases.push((case.to_string(), value, unit.to_string()));
    }

    /// Write `<dir>/<name>.json` where `dir` comes from `BENCH_JSON_DIR`
    /// (default `bench-json`); returns the written path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| "bench-json".into());
        self.write_to(Path::new(&dir))
    }

    /// Write the JSON report into `dir` (created if needed).
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.name));
        let mut cases = String::new();
        for (i, (case, value, unit)) in self.cases.iter().enumerate() {
            if i > 0 {
                cases.push(',');
            }
            cases.push_str(&format!(
                "\n    {{\"name\": {}, \"value\": {}, \"unit\": {}}}",
                json_str(case),
                json_num(*value),
                json_str(unit)
            ));
        }
        let body = format!(
            "{{\n  \"bench\": {},\n  \"smoke\": {},\n  \"wall_s\": {:.6},\n  \"cases\": [{}\n  ]\n}}\n",
            json_str(&self.name),
            smoke(),
            self.started.elapsed().as_secs_f64(),
            cases
        );
        std::fs::write(&path, body)?;
        Ok(path)
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number formatting (non-finite values become `null`).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_at_least_min_iters() {
        let b = Bench { warmup_iters: 0, min_iters: 5, max_iters: 10, budget_s: 0.0 };
        let mut count = 0;
        let r = b.run("noop", || count += 1);
        assert!(r.iters >= 5);
        assert_eq!(count, r.iters);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bench { warmup_iters: 0, min_iters: 1, max_iters: 7, budget_s: 60.0 };
        let r = b.run("noop", || {});
        assert!(r.iters <= 7);
    }

    #[test]
    fn stats_ordered() {
        let r = samples_to_result("x", vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(r.min_s, 1.0);
        assert!(r.p50_s <= r.p99_s);
        assert!((r.mean_s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_inverse_of_mean() {
        let r = samples_to_result("x", vec![0.5, 0.5]);
        assert!((r.throughput(1.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn report_writes_escaped_json() {
        let mut rep = Report::new("unit_test_report");
        rep.case("plain", 1.5, "tok/s");
        rep.case("needs \"escaping\"\n", f64::NAN, "w\\m²");
        let dir = std::env::temp_dir().join(format!("llamaf-bench-json-{}", std::process::id()));
        let path = rep.write_to(&dir).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
        assert!(body.contains("\"bench\": \"unit_test_report\""), "{body}");
        assert!(body.contains("\"value\": 1.5"), "{body}");
        assert!(body.contains("\"value\": null"), "NaN must become null: {body}");
        assert!(body.contains("needs \\\"escaping\\\"\\n"), "{body}");
        assert!(body.contains("w\\\\m²"), "{body}");
        // structurally sane: balanced braces/brackets, no raw control chars
        assert_eq!(body.matches('{').count(), body.matches('}').count());
        assert_eq!(body.matches('[').count(), body.matches(']').count());
        assert!(!body.chars().any(|c| (c as u32) < 0x20 && c != '\n'), "{body:?}");
    }
}
