//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + fixed-duration adaptive iteration, reporting mean / p50 / p99
//! and derived throughput.  Used by every `rust/benches/*.rs` target.

use std::time::Instant;

use crate::util::stats::percentile;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            crate::util::timer::fmt_duration(self.mean_s),
            crate::util::timer::fmt_duration(self.p50_s),
            crate::util::timer::fmt_duration(self.p99_s),
        )
    }
}

/// Benchmark runner with warmup and a wall-clock budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget_s: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, min_iters: 10, max_iters: 10_000, budget_s: 2.0 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, min_iters: 3, max_iters: 100, budget_s: 0.5 }
    }

    /// Run `f` repeatedly; returns timing stats.  `f` should perform one
    /// complete unit of work per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.budget_s && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples_to_result(name, samples)
    }
}

fn samples_to_result(name: &str, mut samples: Vec<f64>) -> BenchResult {
    let iters = samples.len();
    let mean = samples.iter().sum::<f64>() / iters as f64;
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: percentile(&samples, 50.0),
        p99_s: percentile(&samples, 99.0),
        min_s: samples[0],
    }
}

/// Print a section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_at_least_min_iters() {
        let b = Bench { warmup_iters: 0, min_iters: 5, max_iters: 10, budget_s: 0.0 };
        let mut count = 0;
        let r = b.run("noop", || count += 1);
        assert!(r.iters >= 5);
        assert_eq!(count, r.iters);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bench { warmup_iters: 0, min_iters: 1, max_iters: 7, budget_s: 60.0 };
        let r = b.run("noop", || {});
        assert!(r.iters <= 7);
    }

    #[test]
    fn stats_ordered() {
        let r = samples_to_result("x", vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(r.min_s, 1.0);
        assert!(r.p50_s <= r.p99_s);
        assert!((r.mean_s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_inverse_of_mean() {
        let r = samples_to_result("x", vec![0.5, 0.5]);
        assert!((r.throughput(1.0) - 2.0).abs() < 1e-9);
    }
}
