//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + fixed-duration adaptive iteration, reporting mean / p50 / p99
//! and derived throughput.  Used by every `rust/benches/*.rs` target.
//!
//! Two CI-facing facilities live here too:
//!
//! * [`smoke`] — `BENCH_SMOKE=1` puts every harness-driven bench into a
//!   one-quick-iteration mode so the CI `bench-smoke` job can compile and
//!   run the whole `rust/benches/` suite in seconds (drift caught at PR
//!   time, not at measurement time).
//! * [`Report`] — each bench target records its headline numbers and
//!   writes one JSON file (`BENCH_JSON_DIR`, default `bench-json/`); CI
//!   uploads the directory as a workflow artifact.
//! * [`parse_report`] / [`diff_cases`] — read a previously written
//!   report back and compare runs case by case, classifying changes as
//!   regressions by unit direction (`tok/s` up is good, `s` up is bad).
//!   The CI bench-smoke job downloads the previous run's artifact and
//!   fails (advisorily) on >20% regressions via `llamaf bench-diff`.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::stats::percentile;

/// True when `BENCH_SMOKE=1` is set: benches run one quick iteration per
/// case (the CI smoke mode) instead of their full measurement budget.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.mean_s
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            crate::util::timer::fmt_duration(self.mean_s),
            crate::util::timer::fmt_duration(self.p50_s),
            crate::util::timer::fmt_duration(self.p99_s),
        )
    }
}

/// Benchmark runner with warmup and a wall-clock budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget_s: f64,
}

impl Default for Bench {
    /// Full measurement budget — or the smoke settings when
    /// `BENCH_SMOKE=1`, so CI never pays for statistics it discards.
    fn default() -> Self {
        if smoke() {
            return Bench { warmup_iters: 0, min_iters: 1, max_iters: 1, budget_s: 0.0 };
        }
        Bench { warmup_iters: 3, min_iters: 10, max_iters: 10_000, budget_s: 2.0 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        if smoke() {
            return Bench::default();
        }
        Bench { warmup_iters: 1, min_iters: 3, max_iters: 100, budget_s: 0.5 }
    }

    /// Run `f` repeatedly; returns timing stats.  `f` should perform one
    /// complete unit of work per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.budget_s && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples_to_result(name, samples)
    }
}

fn samples_to_result(name: &str, mut samples: Vec<f64>) -> BenchResult {
    let iters = samples.len();
    let mean = samples.iter().sum::<f64>() / iters as f64;
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: percentile(&samples, 50.0),
        p99_s: percentile(&samples, 99.0),
        min_s: samples[0],
    }
}

/// Print a section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable bench report.  Each bench target records its headline
/// numbers via [`Report::case`] and writes one JSON file at exit; the CI
/// `bench-smoke` job uploads the directory as a workflow artifact so
/// bench output (and any drift in it) is inspectable per PR.
pub struct Report {
    name: String,
    started: Instant,
    cases: Vec<(String, f64, String)>,
}

impl Report {
    /// Start a report for the bench target `name` (used as the file stem).
    pub fn new(name: &str) -> Self {
        Report { name: name.to_string(), started: Instant::now(), cases: Vec::new() }
    }

    /// Record one headline number (`value` in `unit`) under `case`.
    pub fn case(&mut self, case: &str, value: f64, unit: &str) {
        self.cases.push((case.to_string(), value, unit.to_string()));
    }

    /// Write `<dir>/<name>.json` where `dir` comes from `BENCH_JSON_DIR`
    /// (default `bench-json`); returns the written path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| "bench-json".into());
        self.write_to(Path::new(&dir))
    }

    /// Write the JSON report into `dir` (created if needed).
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.name));
        let mut cases = String::new();
        for (i, (case, value, unit)) in self.cases.iter().enumerate() {
            if i > 0 {
                cases.push(',');
            }
            cases.push_str(&format!(
                "\n    {{\"name\": {}, \"value\": {}, \"unit\": {}}}",
                json_str(case),
                json_num(*value),
                json_str(unit)
            ));
        }
        let body = format!(
            "{{\n  \"bench\": {},\n  \"smoke\": {},\n  \"wall_s\": {:.6},\n  \"cases\": [{}\n  ]\n}}\n",
            json_str(&self.name),
            smoke(),
            self.started.elapsed().as_secs_f64(),
            cases
        );
        std::fs::write(&path, body)?;
        Ok(path)
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number formatting (non-finite values become `null`).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

// ---------------------------------------------------------------------------
// Run-to-run regression diffing
// ---------------------------------------------------------------------------

/// One case parsed back out of a written report.
#[derive(Clone, Debug, PartialEq)]
pub struct ReportCase {
    /// Case name as recorded by [`Report::case`].
    pub name: String,
    /// Recorded headline value (cases whose value was `null` are dropped
    /// at parse time).
    pub value: f64,
    /// Unit string; drives the regression direction heuristic.
    pub unit: String,
}

/// Whether a larger value of `unit` is an improvement.  Time-, volume-
/// and count-like families regress upward (`s`, `ms`, `B/tok`, `MB`,
/// `calls` — more seconds/bytes/dispatches is worse); everything else
/// (`GOPS`, `tok/s`, speedup factors) regresses downward.  Matched by
/// family, not exact string, so unit variants a future bench invents
/// (`us/tok`, `KiB`, `iters`) inherit the right direction instead of
/// silently inverting the advisory regression gate.
pub fn higher_is_better(unit: &str) -> bool {
    let time = matches!(unit, "s" | "ms" | "us" | "ns") || unit.starts_with("s/");
    let volume = matches!(unit, "B" | "bytes" | "KB" | "KiB" | "MB" | "MiB" | "GB" | "GiB");
    let count = matches!(unit, "calls" | "iters" | "spawns" | "transfers");
    let per_unit_cost =
        unit.ends_with("/tok") || unit.ends_with("/iter") || unit.ends_with("/step");
    !(time || volume || count || per_unit_cost)
}

/// One compared case of a run-to-run diff.
#[derive(Clone, Debug)]
pub struct DiffEntry {
    /// Case name shared by both runs.
    pub name: String,
    /// Unit string of the current run's case.
    pub unit: String,
    /// Previous run's value.
    pub prev: f64,
    /// Current run's value.
    pub cur: f64,
    /// Fractional change in the *worse* direction for this unit: +0.25
    /// means 25% worse (slower / more bytes), negative means improved.
    pub regression: f64,
}

impl DiffEntry {
    /// Human-readable one-liner for logs.
    pub fn row(&self) -> String {
        // print the raw signed change; `regression` already folds in the
        // unit direction, so undo it for display
        let change = if higher_is_better(&self.unit) { -self.regression } else { self.regression };
        format!(
            "{:<40} {:>14.4} -> {:>14.4} {:<6} {:+.1}%{}",
            self.name,
            self.prev,
            self.cur,
            self.unit,
            100.0 * change,
            if self.regression > 0.0 { "  (worse)" } else { "" },
        )
    }
}

/// Extract a JSON string field (`"key": "..."`) from one case object
/// written by [`Report::write_to`], undoing its escaping.
fn field_str(obj: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\": \"");
    let start = obj.find(&pat)? + pat.len();
    let mut out = String::new();
    let mut chars = obj[start..].chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => return Some(out),
            '\\' => match chars.next()? {
                'n' => out.push('\n'),
                'r' => out.push('\r'),
                't' => out.push('\t'),
                'u' => {
                    let hex: String = chars.by_ref().take(4).collect();
                    out.push(char::from_u32(u32::from_str_radix(&hex, 16).ok()?)?);
                }
                other => out.push(other), // covers \" and \\
            },
            c => out.push(c),
        }
    }
    None
}

/// Extract a JSON number field (`"key": 1.5`); `null` parses as `None`.
fn field_num(obj: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\": ");
    let start = obj.find(&pat)? + pat.len();
    let rest = &obj[start..];
    let end = rest.find([',', '}']).unwrap_or(rest.len());
    let tok = rest[..end].trim();
    if tok == "null" {
        return None;
    }
    tok.parse().ok()
}

/// Parse the cases out of a report body written by [`Report::write_to`].
/// Only this crate's own format is supported (one case object per line);
/// anything unrecognized is skipped rather than an error, so a corrupt
/// or foreign artifact degrades to "nothing to compare".
pub fn parse_report(body: &str) -> Vec<ReportCase> {
    let Some(pos) = body.find("\"cases\":") else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for line in body[pos..].lines() {
        let t = line.trim().trim_end_matches(',');
        if !t.starts_with('{') {
            continue;
        }
        let (Some(name), Some(unit)) = (field_str(t, "name"), field_str(t, "unit")) else {
            continue;
        };
        if let Some(value) = field_num(t, "value") {
            out.push(ReportCase { name, value, unit });
        }
    }
    out
}

/// Compare two case lists name by name.  Cases present in only one run,
/// non-finite values, and zero baselines are skipped (nothing meaningful
/// to report).
pub fn diff_cases(prev: &[ReportCase], cur: &[ReportCase]) -> Vec<DiffEntry> {
    let mut out = Vec::new();
    for c in cur {
        let Some(p) = prev.iter().find(|p| p.name == c.name) else {
            continue;
        };
        if !p.value.is_finite() || !c.value.is_finite() || p.value == 0.0 {
            continue;
        }
        let change = (c.value - p.value) / p.value.abs();
        let regression = if higher_is_better(&c.unit) { -change } else { change };
        out.push(DiffEntry {
            name: c.name.clone(),
            unit: c.unit.clone(),
            prev: p.value,
            cur: c.value,
            regression,
        });
    }
    out
}

impl Report {
    /// Diff this report's recorded cases against a previously written
    /// JSON body (e.g. the same bench's file from the last CI run).
    pub fn diff(&self, prev_json: &str) -> Vec<DiffEntry> {
        let prev = parse_report(prev_json);
        let cur: Vec<ReportCase> = self
            .cases
            .iter()
            .map(|(name, value, unit)| ReportCase {
                name: name.clone(),
                value: *value,
                unit: unit.clone(),
            })
            .collect();
        diff_cases(&prev, &cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_at_least_min_iters() {
        let b = Bench { warmup_iters: 0, min_iters: 5, max_iters: 10, budget_s: 0.0 };
        let mut count = 0;
        let r = b.run("noop", || count += 1);
        assert!(r.iters >= 5);
        assert_eq!(count, r.iters);
    }

    #[test]
    fn respects_max_iters() {
        let b = Bench { warmup_iters: 0, min_iters: 1, max_iters: 7, budget_s: 60.0 };
        let r = b.run("noop", || {});
        assert!(r.iters <= 7);
    }

    #[test]
    fn stats_ordered() {
        let r = samples_to_result("x", vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(r.min_s, 1.0);
        assert!(r.p50_s <= r.p99_s);
        assert!((r.mean_s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_inverse_of_mean() {
        let r = samples_to_result("x", vec![0.5, 0.5]);
        assert!((r.throughput(1.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn parse_report_roundtrips_written_cases() {
        let mut rep = Report::new("roundtrip");
        rep.case("throughput", 123.5, "tok/s");
        rep.case("staging", 2.5e6, "B/tok");
        rep.case("weird \"name\"\t", 0.25, "x");
        rep.case("broken", f64::NAN, "GOPS"); // null -> dropped at parse
        let dir = std::env::temp_dir().join(format!("llamaf-bench-rt-{}", std::process::id()));
        let path = rep.write_to(&dir).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
        let cases = parse_report(&body);
        assert_eq!(
            cases,
            vec![
                ReportCase { name: "throughput".into(), value: 123.5, unit: "tok/s".into() },
                ReportCase { name: "staging".into(), value: 2.5e6, unit: "B/tok".into() },
                ReportCase { name: "weird \"name\"\t".into(), value: 0.25, unit: "x".into() },
            ]
        );
        assert!(parse_report("not json at all").is_empty());
    }

    #[test]
    fn diff_classifies_regressions_by_unit_direction() {
        let prev = vec![
            ReportCase { name: "rate".into(), value: 100.0, unit: "tok/s".into() },
            ReportCase { name: "lat".into(), value: 0.010, unit: "s".into() },
            ReportCase { name: "gone".into(), value: 1.0, unit: "x".into() },
            ReportCase { name: "zero".into(), value: 0.0, unit: "x".into() },
        ];
        let cur = vec![
            // tok/s fell 30%: a regression of +0.30
            ReportCase { name: "rate".into(), value: 70.0, unit: "tok/s".into() },
            // latency fell 50%: an improvement (negative regression)
            ReportCase { name: "lat".into(), value: 0.005, unit: "s".into() },
            ReportCase { name: "new".into(), value: 5.0, unit: "x".into() },
            ReportCase { name: "zero".into(), value: 3.0, unit: "x".into() },
        ];
        let diffs = diff_cases(&prev, &cur);
        assert_eq!(diffs.len(), 2, "unpaired and zero-baseline cases skipped: {diffs:?}");
        let rate = diffs.iter().find(|d| d.name == "rate").unwrap();
        assert!((rate.regression - 0.30).abs() < 1e-9, "{rate:?}");
        assert!(rate.row().contains("worse"), "{}", rate.row());
        let lat = diffs.iter().find(|d| d.name == "lat").unwrap();
        assert!((lat.regression + 0.50).abs() < 1e-9, "{lat:?}");
        assert!(!lat.row().contains("worse"));
    }

    #[test]
    fn report_diff_against_previous_json() {
        let mut prev = Report::new("same");
        prev.case("gops", 4.0, "GOPS");
        let dir = std::env::temp_dir().join(format!("llamaf-bench-diff-{}", std::process::id()));
        let path = prev.write_to(&dir).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
        let mut cur = Report::new("same");
        cur.case("gops", 3.0, "GOPS");
        let diffs = cur.diff(&body);
        assert_eq!(diffs.len(), 1);
        assert!((diffs[0].regression - 0.25).abs() < 1e-9, "{:?}", diffs[0]);
    }

    #[test]
    fn unit_direction_heuristic() {
        for unit in ["GOPS", "tok/s", "x", "layers"] {
            assert!(higher_is_better(unit), "{unit}");
        }
        // dispatch/quantization counts regress UP: the 7 -> 4 fused-layer
        // reduction must be guarded, not celebrated, by the differ —
        // and family matching covers variants no bench emits yet
        for unit in ["s", "ms", "B/tok", "bytes", "calls", "us/tok", "MiB", "iters", "ms/step"] {
            assert!(!higher_is_better(unit), "{unit}");
        }
    }

    #[test]
    fn report_writes_escaped_json() {
        let mut rep = Report::new("unit_test_report");
        rep.case("plain", 1.5, "tok/s");
        rep.case("needs \"escaping\"\n", f64::NAN, "w\\m²");
        let dir = std::env::temp_dir().join(format!("llamaf-bench-json-{}", std::process::id()));
        let path = rep.write_to(&dir).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
        assert!(body.contains("\"bench\": \"unit_test_report\""), "{body}");
        assert!(body.contains("\"value\": 1.5"), "{body}");
        assert!(body.contains("\"value\": null"), "NaN must become null: {body}");
        assert!(body.contains("needs \\\"escaping\\\"\\n"), "{body}");
        assert!(body.contains("w\\\\m²"), "{body}");
        // structurally sane: balanced braces/brackets, no raw control chars
        assert_eq!(body.matches('{').count(), body.matches('}').count());
        assert_eq!(body.matches('[').count(), body.matches(']').count());
        assert!(!body.chars().any(|c| (c as u32) < 0x20 && c != '\n'), "{body:?}");
    }
}
